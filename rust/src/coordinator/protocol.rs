//! Line-delimited JSON serving protocol.
//!
//! Requests (one JSON object per line):
//!   {"op":"ping"}
//!   {"op":"info"}
//!   {"op":"metrics"}
//!   {"op":"eval","model":"cifar8"}
//!   {"op":"sample","model":"cifar8","method":"fpi","n":4,"seed":0,
//!    "t_use":1,"return_samples":true,"decode":false}
//!
//! Responses: {"ok":true, ...} or {"ok":false,"error":"..."}.
//!
//! `info` and `metrics` report the engine-worker pool: `engine_workers`
//! (shard count) and a `workers` array of per-worker gauges — queue depth,
//! occupancy, loaded engines, batch/sample/error counters, and the
//! policy-layer gauges (per-policy schedule counters, absorption
//! counters, queue-age histogram). `sample` responses carry `arm_calls`
//! (batched ARM invocations for the whole group), `calls_per_job`
//! (passes × batch / jobs — the batched cost model) and `calls_pct`
//! (`calls_per_job` as % of the baseline's d).
//!
//! The full wire contract — field tables, error and EOF semantics, and a
//! worked request/response example per method — lives in
//! `docs/PROTOCOL.md`.

use crate::coordinator::config::Method;
use crate::substrate::json::{self, Value};

/// Parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Info,
    Metrics,
    Eval { model: String },
    Sample {
        model: String,
        method: Method,
        n: usize,
        seed: u64,
        return_samples: bool,
        decode: bool,
    },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
        let op = v.get("op").as_str().ok_or("missing op")?;
        match op {
            "ping" => Ok(Request::Ping),
            "info" => Ok(Request::Info),
            "metrics" => Ok(Request::Metrics),
            "eval" => Ok(Request::Eval {
                model: v.get("model").as_str().ok_or("eval: missing model")?.to_string(),
            }),
            "sample" => {
                let model = v.get("model").as_str().ok_or("sample: missing model")?.to_string();
                let method_name = v.get("method").as_str().unwrap_or("fpi");
                let t_use = v.get("t_use").as_usize().unwrap_or(1);
                let method = Method::parse(method_name, t_use).ok_or_else(|| format!("unknown method {method_name}"))?;
                Ok(Request::Sample {
                    model,
                    method,
                    n: v.get("n").as_usize().unwrap_or(1).max(1),
                    seed: v.get("seed").as_i64().unwrap_or(0) as u64,
                    return_samples: v.get("return_samples").as_bool().unwrap_or(true),
                    decode: v.get("decode").as_bool().unwrap_or(false),
                })
            }
            other => Err(format!("unknown op {other}")),
        }
    }
}

/// Build the wire form of a response value.
pub fn ok(fields: Vec<(&str, Value)>) -> String {
    let mut all = vec![("ok", Value::Bool(true))];
    all.extend(fields);
    Value::obj(all).to_string()
}

pub fn err(msg: &str) -> String {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::str(msg))]).to_string()
}

/// Encode a batch of integer samples.
pub fn samples_value(samples: &[Vec<i32>]) -> Value {
    Value::Arr(
        samples
            .iter()
            .map(|row| Value::Arr(row.iter().map(|&v| Value::num(v as f64)).collect()))
            .collect(),
    )
}

/// Decode a samples array from a response.
pub fn parse_samples(v: &Value) -> Option<Vec<Vec<i32>>> {
    v.as_arr().map(|rows| {
        rows.iter()
            .map(|r| r.as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_request() {
        let r = Request::parse(r#"{"op":"sample","model":"cifar8","method":"forecast","t_use":5,"n":3,"seed":9}"#).unwrap();
        assert_eq!(
            r,
            Request::Sample {
                model: "cifar8".into(),
                method: Method::Forecast { t_use: 5 },
                n: 3,
                seed: 9,
                return_samples: true,
                decode: false,
            }
        );
    }

    #[test]
    fn defaults_applied() {
        let r = Request::parse(r#"{"op":"sample","model":"m"}"#).unwrap();
        match r {
            Request::Sample { method, n, seed, .. } => {
                assert_eq!(method, Method::Fpi);
                assert_eq!(n, 1);
                assert_eq!(seed, 0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"sample"}"#).is_err());
        assert!(Request::parse(r#"{"op":"frobnicate"}"#).is_err());
        assert!(Request::parse(r#"{"op":"sample","model":"m","method":"nope"}"#).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = ok(vec![("arm_calls", Value::num(42.0)), ("samples", samples_value(&[vec![1, 2], vec![3, 4]]))]);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(parse_samples(v.get("samples")).unwrap(), vec![vec![1, 2], vec![3, 4]]);
        let e = err("boom");
        let v = json::parse(&e).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("error").as_str(), Some("boom"));
    }
}
