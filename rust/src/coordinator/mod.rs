//! The serving layer (L3): everything between a sample request and the
//! predictive-sampling engine.
//!
//! * [`engine`] — owns the compiled executables for one model and runs
//!   the sampling methods against them.
//! * [`scheduler`] — elastic continuous batching: converged batch slots
//!   are refilled from a live queue mid-flight, and the schedule
//!   up-/down-shifts across the exported batch sizes as that queue grows
//!   and drains. This is the "scheduling system" the paper explicitly
//!   leaves to future work (§4.1), which lets batched serving approach
//!   the batch-size-1 ARM-call rate.
//! * [`policy`] — the pluggable decisions on top of that machinery:
//!   batch *sizing* (occupancy-first / latency-lean / SLO-driven hybrid)
//!   and mid-flight *admission* (age-based oldest-first fairness, or the
//!   legacy absorb budget). Policies move work around but never change
//!   samples.
//! * [`router`] — model-name → engine dispatch with LRU eviction.
//! * [`placement`] — the placement plane: which workers may *own* which
//!   models. Replicate-all (the default), explicit per-model worker
//!   pins, or an LRU-evicted per-worker engine cap; eligibility threads
//!   through routing, stealing, and eval dispatch.
//! * [`protocol`] + [`server`] — line-delimited-JSON TCP serving over a
//!   sharded engine-worker pool: PJRT handles are not `Send`, so each of
//!   the `engine_threads` workers owns its own `Router` (engines loaded
//!   lazily where placement allows) and a dispatcher routes each
//!   `(model, method)` batching group to the least-loaded *eligible*
//!   worker, preferring warm ones among ties. Executing groups absorb
//!   their own mid-flight arrivals; idle workers steal whole queued
//!   groups they can host from loaded ones.
//! * [`metrics`] — request/latency/ARM-call accounting, per worker,
//!   aggregated into one snapshot with queue-depth/occupancy/steal
//!   gauges plus the placement plane's residency gauges.
//! * [`federation`] — the placement plane one level up: a front-tier
//!   router (`predsamp route`) that fans model namespaces across N
//!   backend coordinator *processes* over persistent pipelined
//!   connections, health-checks them, and re-homes a dead process's
//!   namespaces exactly like the pool re-homes a dead worker's groups.

pub mod config;
pub mod engine;
pub mod federation;
pub mod metrics;
pub mod placement;
pub mod policy;
pub mod protocol;
pub mod router;
pub mod scheduler;
pub mod server;
