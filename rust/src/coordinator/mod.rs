//! The serving layer (L3): everything between a sample request and the
//! predictive-sampling engine.
//!
//! * [`engine`] — owns the compiled executables for one model and runs
//!   the sampling methods against them.
//! * [`batcher`] — dynamic batching queue (size/deadline policy).
//! * [`scheduler`] — continuous batching: converged batch slots are
//!   refilled from the queue mid-flight. This is the "scheduling system"
//!   the paper explicitly leaves to future work (§4.1), which lets batched
//!   serving approach the batch-size-1 ARM-call rate.
//! * [`router`] — model-name → engine dispatch.
//! * [`protocol`] + [`server`] — line-delimited-JSON TCP serving over a
//!   sharded engine-worker pool: PJRT handles are not `Send`, so each of
//!   the `engine_threads` workers owns its own `Router` (engines
//!   replicated lazily) and a dispatcher routes each `(model, method)`
//!   batching group to the least-loaded worker.
//! * [`metrics`] — request/latency/ARM-call accounting, per worker,
//!   aggregated into one snapshot with queue-depth/occupancy gauges.

pub mod batcher;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod scheduler;
pub mod server;
