//! The placement plane: which engine workers may own which models.
//!
//! PRs 1–4 replicated every engine on every worker (`Router` per thread,
//! engines loaded lazily on first touch), which multiplies compile time
//! and memory by `engine_threads` — worker memory becomes the scaling
//! wall as the manifest grows heterogeneous (explicit-likelihood ARMs
//! next to latent models with heavyweight decoders). This module makes
//! ownership an explicit, pluggable decision:
//!
//! * [`ReplicateAll`] — every worker may own every model (the default;
//!   bit-identical to the pre-placement fleet).
//! * [`Pinned`] — models pinned to explicit worker subsets, from the
//!   manifest's `"pin": [0, 2]` field and/or the CLI's repeatable
//!   `--pin model=0,2`. Unpinned models still replicate anywhere.
//! * [`CapacityCapped`] — every worker is eligible for every model, but
//!   at most `max_engines` engines stay resident per worker; the
//!   least-recently-used engine is evicted beyond that
//!   ([`crate::coordinator::router::Router::enforce_cap`]).
//!
//! Eligibility threads through every layer that used to assume
//! replicate-all: the dispatcher routes fresh `(model, method)` groups —
//! and evals — only to eligible workers (preferring, among least-loaded
//! ties, workers with the engine already warm), group stealing skips
//! groups the thief may not host, and the per-worker resident-model /
//! `engine_loads` / `evictions` gauges feed the `metrics` snapshot.
//! Placement only moves groups between workers; per-job noise is keyed
//! by `(seed, job index)`, so samples are bitwise identical under every
//! policy (`rust/tests/server_test.rs`).
#![deny(missing_docs)]

use crate::runtime::artifact::Manifest;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Serving-config selector for the placement policy (`--placement`,
/// `--pin`, `--max-engines`). Resolved against the manifest and worker
/// count by [`placement_for`] at server spawn.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// [`ReplicateAll`] (the default).
    ReplicateAll,
    /// [`Pinned`]: the CLI `--pin model=workers` entries; manifest
    /// `"pin"` fields merge in at spawn, with CLI entries winning per
    /// model.
    Pinned(Vec<(String, Vec<usize>)>),
    /// [`CapacityCapped`] with the given per-worker engine budget
    /// (`--max-engines`).
    CapacityCapped(usize),
}

impl PlacementKind {
    /// The canonical `--placement` spelling.
    pub fn label(&self) -> &'static str {
        match self {
            PlacementKind::ReplicateAll => "replicate",
            PlacementKind::Pinned(_) => "pinned",
            PlacementKind::CapacityCapped(_) => "capped",
        }
    }
}

/// A placement policy: the worker-eligibility rule the dispatcher, the
/// work-stealing path, and eval routing all consult, plus the per-worker
/// residency bound capacity enforcement runs under.
///
/// Contract: `eligible` must be stable for the lifetime of the server
/// (routing caches nothing, but a group stolen by an eligible thief must
/// stay hostable), and at least one worker must be eligible for every
/// servable model — [`placement_for`] validates that at spawn. Placement
/// never touches job noise, so it can never change a sample.
pub trait PlacementPolicy: Send + Sync {
    /// Stable label for the `info`/`metrics` responses.
    fn name(&self) -> &'static str;
    /// Whether `worker` may host `model`'s engine.
    fn eligible(&self, model: &str, worker: usize) -> bool;
    /// Upper bound on engines resident per worker (`None` = unlimited).
    fn max_resident(&self) -> Option<usize> {
        None
    }
}

/// Every worker may own every model — the pre-placement fleet, and the
/// default. Existing serving trajectories are bit-identical under it.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicateAll;

impl PlacementPolicy for ReplicateAll {
    fn name(&self) -> &'static str {
        "replicate"
    }
    fn eligible(&self, _model: &str, _worker: usize) -> bool {
        true
    }
}

/// Models pinned to explicit worker subsets; unpinned models replicate
/// anywhere. Build via [`placement_for`], which merges manifest pins
/// with CLI pins and validates worker indices.
#[derive(Clone, Debug)]
pub struct Pinned {
    /// model → eligible worker indices (non-empty, validated in range).
    pins: BTreeMap<String, Vec<usize>>,
}

impl Pinned {
    /// The resolved pin table (gauges and tests).
    pub fn pins(&self) -> &BTreeMap<String, Vec<usize>> {
        &self.pins
    }
}

impl PlacementPolicy for Pinned {
    fn name(&self) -> &'static str {
        "pinned"
    }
    fn eligible(&self, model: &str, worker: usize) -> bool {
        self.pins.get(model).map(|ws| ws.contains(&worker)).unwrap_or(true)
    }
}

/// Every worker is eligible for every model, but at most `max_engines`
/// engines stay resident per worker — before a missing engine loads,
/// the worker evicts least-recently-used ones to make room (so
/// residency never exceeds the cap, even transiently), trading reload
/// latency for a hard per-worker memory bound.
#[derive(Clone, Copy, Debug)]
pub struct CapacityCapped {
    /// Engines allowed resident per worker (≥ 1).
    pub max_engines: usize,
}

impl PlacementPolicy for CapacityCapped {
    fn name(&self) -> &'static str {
        "capped"
    }
    fn eligible(&self, _model: &str, _worker: usize) -> bool {
        true
    }
    fn max_resident(&self) -> Option<usize> {
        Some(self.max_engines)
    }
}

/// Parse one `--pin model=0,2` argument into `(model, workers)`.
pub fn parse_pin(arg: &str) -> Result<(String, Vec<usize>)> {
    let (model, list) = arg.split_once('=').ok_or_else(|| anyhow!("--pin {arg:?}: expected model=W[,W...]"))?;
    ensure!(!model.is_empty(), "--pin {arg:?}: empty model name");
    let workers = list
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|_| anyhow!("--pin {arg:?}: bad worker index {s:?}")))
        .collect::<Result<Vec<usize>>>()?;
    ensure!(!workers.is_empty(), "--pin {arg:?}: empty worker list");
    Ok((model.to_string(), workers))
}

/// Resolve a [`PlacementKind`] into the policy a server runs under:
/// merges manifest `"pin"` fields with CLI pins (CLI wins per model) and
/// validates that every pin names a known model, a non-empty in-range
/// worker set — so a typo fails at spawn, not as a routing dead-end.
pub fn placement_for(kind: &PlacementKind, manifest: &Manifest, n_workers: usize) -> Result<Arc<dyn PlacementPolicy>> {
    match kind {
        PlacementKind::ReplicateAll => Ok(Arc::new(ReplicateAll)),
        PlacementKind::CapacityCapped(cap) => {
            ensure!(*cap >= 1, "placement: --max-engines must be >= 1");
            Ok(Arc::new(CapacityCapped { max_engines: *cap }))
        }
        PlacementKind::Pinned(cli) => {
            let mut pins: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for (name, info) in &manifest.models {
                if let Some(p) = &info.pin {
                    pins.insert(name.clone(), p.clone());
                }
            }
            for (model, workers) in cli {
                ensure!(
                    manifest.models.contains_key(model),
                    "--pin {model}: unknown model (have {:?})",
                    manifest.models.keys().collect::<Vec<_>>()
                );
                pins.insert(model.clone(), workers.clone());
            }
            for (model, workers) in &pins {
                ensure!(!workers.is_empty(), "model {model}: empty pin list");
                for &w in workers {
                    ensure!(w < n_workers, "model {model} pinned to worker {w}, but only {n_workers} engine workers exist");
                }
            }
            Ok(Arc::new(Pinned { pins }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{write_mock_manifest, MockModelSpec};

    fn manifest_with_pins() -> Manifest {
        let dir = std::env::temp_dir().join(format!("predsamp-placement-{}", std::process::id()));
        let mut a = MockModelSpec::new("pin_a", 1);
        a.pin = Some(vec![0]);
        let b = MockModelSpec::new("free_b", 2);
        write_mock_manifest(&dir, &[a, b]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        man
    }

    #[test]
    fn replicate_all_is_always_eligible() {
        let p = ReplicateAll;
        assert!(p.eligible("anything", 0) && p.eligible("anything", 7));
        assert_eq!(p.max_resident(), None);
        assert_eq!(p.name(), "replicate");
    }

    #[test]
    fn pinned_restricts_pinned_models_only() {
        let man = manifest_with_pins();
        let p = placement_for(&PlacementKind::Pinned(Vec::new()), &man, 2).unwrap();
        assert_eq!(p.name(), "pinned");
        assert!(p.eligible("pin_a", 0), "manifest pin admits its worker");
        assert!(!p.eligible("pin_a", 1), "manifest pin excludes other workers");
        assert!(p.eligible("free_b", 0) && p.eligible("free_b", 1), "unpinned models replicate anywhere");
        assert_eq!(p.max_resident(), None);
    }

    #[test]
    fn cli_pin_overrides_manifest_pin() {
        let man = manifest_with_pins();
        let cli = vec![("pin_a".to_string(), vec![1])];
        let p = placement_for(&PlacementKind::Pinned(cli), &man, 2).unwrap();
        assert!(!p.eligible("pin_a", 0) && p.eligible("pin_a", 1), "a CLI pin must win over the manifest's");
    }

    #[test]
    fn pin_validation_fails_fast() {
        let man = manifest_with_pins();
        // Manifest pin to worker 0 needs >= 1 workers; CLI pin beyond the
        // fleet, to an unknown model, or empty must all fail at spawn.
        assert!(placement_for(&PlacementKind::Pinned(vec![("pin_a".into(), vec![5])]), &man, 2).is_err(), "out-of-range worker");
        assert!(placement_for(&PlacementKind::Pinned(vec![("nope".into(), vec![0])]), &man, 2).is_err(), "unknown model");
        assert!(placement_for(&PlacementKind::Pinned(vec![("free_b".into(), vec![])]), &man, 2).is_err(), "empty pin list");
        assert!(placement_for(&PlacementKind::CapacityCapped(0), &man, 2).is_err(), "zero engine budget");
    }

    #[test]
    fn capacity_capped_bounds_residency_not_eligibility() {
        let man = manifest_with_pins();
        let p = placement_for(&PlacementKind::CapacityCapped(1), &man, 4).unwrap();
        assert_eq!(p.name(), "capped");
        assert!(p.eligible("pin_a", 3), "capacity capping never restricts routing");
        assert_eq!(p.max_resident(), Some(1));
    }

    #[test]
    fn pin_arg_parsing() {
        assert_eq!(parse_pin("m=0,2").unwrap(), ("m".to_string(), vec![0, 2]));
        assert_eq!(parse_pin("m=1").unwrap(), ("m".to_string(), vec![1]));
        assert!(parse_pin("m").is_err(), "missing =");
        assert!(parse_pin("=0").is_err(), "empty model");
        assert!(parse_pin("m=").is_err(), "empty worker list");
        assert!(parse_pin("m=x").is_err(), "non-numeric worker");
    }

    #[test]
    fn kind_labels_are_stable() {
        assert_eq!(PlacementKind::ReplicateAll.label(), "replicate");
        assert_eq!(PlacementKind::Pinned(Vec::new()).label(), "pinned");
        assert_eq!(PlacementKind::CapacityCapped(2).label(), "capped");
    }
}
