//! Criterion-lite: warmup + N timed iterations + Bessel-corrected summary.
//! (criterion is unavailable offline; cargo-bench targets use
//! `harness = false` and call this.)

use crate::substrate::stats::Summary;
use crate::substrate::timer::{fmt_duration, Timer};

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} ±{:>9}  (n={})",
            self.name,
            fmt_duration(self.secs.mean),
            fmt_duration(self.secs.std),
            self.iters
        )
    }
}

/// Run `f` `warmup` + `iters` times, timing the `iters` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    BenchResult { name: name.to_string(), secs: Summary::of(&times), iters }
}

/// Run a fallible closure once per seed, collecting a metric per run.
pub fn per_seed<F>(seeds: &[u64], mut f: F) -> Vec<f64>
where
    F: FnMut(u64) -> f64,
{
    seeds.iter().map(|&s| f(s)).collect()
}

/// The seed protocol of the paper's tables ({0..n-1}).
pub fn seed_range(n: usize) -> Vec<u64> {
    (0..n as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let r = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.secs.mean >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn seed_protocol() {
        assert_eq!(seed_range(3), vec![0, 1, 2]);
        let vals = per_seed(&seed_range(4), |s| s as f64 * 2.0);
        assert_eq!(vals, vec![0.0, 2.0, 4.0, 6.0]);
    }
}
