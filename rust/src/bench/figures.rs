//! Regenerators for the paper's Figures 3-6 (samples, forecast-mistake
//! overlays, convergence heatmaps). Output: PPM files under `results/`
//! plus coarse ASCII previews on stdout.

use crate::coordinator::config::Method;
use crate::coordinator::engine::Engine;
use crate::runtime::artifact::Manifest;
use crate::sampler::trace;
use crate::substrate::image::Image;
use anyhow::Result;
use std::path::Path;

/// Figures 3/4 (and appendix 7-10): samples from an explicit-likelihood
/// ARM with mistake overlays for both learned forecasting and FPI.
/// Returns the written file paths.
pub fn fig_samples(manifest: &Manifest, model: &str, out_dir: &Path, seed: u64, t_use: usize) -> Result<Vec<String>> {
    let engine = Engine::load(manifest, model)?;
    let info = &engine.info;
    let batch = *engine.batch_sizes().last().unwrap();
    let n_show = batch.min(16);
    let mut written = Vec::new();

    for (tag, method) in [
        ("forecast", Method::Forecast { t_use }),
        ("fpi", Method::Fpi),
    ] {
        let res = engine.sample_batch(method, batch, seed)?;
        let tiles: Vec<Image> = res.jobs[..n_show]
            .iter()
            .map(|j| trace::render_with_mistakes(j, info.width, info.height, info.channels, info.categories).upscale(4))
            .collect();
        let grid = Image::grid(&tiles, 4);
        let path = out_dir.join(format!("{model}_{tag}_mistakes.ppm"));
        grid.write_ppm(&path)?;
        written.push(path.display().to_string());
        // Pure samples (panel a) only need one method — they're identical
        // by the exactness guarantee.
        if tag == "fpi" {
            let tiles: Vec<Image> = res.jobs[..n_show]
                .iter()
                .map(|j| {
                    let im = if info.channels >= 3 {
                        trace::render_rgb(j, info.width, info.height, info.channels, info.categories)
                    } else {
                        trace::render_gray(j, info.width, info.height, info.categories)
                    };
                    im.upscale(4)
                })
                .collect();
            let path = out_dir.join(format!("{model}_samples.ppm"));
            Image::grid(&tiles, 4).write_ppm(&path)?;
            written.push(path.display().to_string());
            println!("{model} sample 0 (ascii):");
            print!("{}", trace::render_with_mistakes(&res.jobs[0], info.width, info.height, info.channels, info.categories).to_ascii());
        }
        let total_mistakes: usize = res.jobs[..n_show].iter().flat_map(|j| j.mistakes.iter().map(|&m| m as usize)).sum();
        println!(
            "{model} {tag}: {} ARM calls ({:.1}%), {} mistakes / {} vars shown",
            res.arm_calls,
            res.calls_pct(info.dim),
            total_mistakes,
            n_show * info.dim
        );
    }
    Ok(written)
}

/// Figure 5: VAE samples — latents sampled by FPI/forecast, decoded to
/// images, with latent-space mistake maps upscaled alongside.
pub fn fig5(manifest: &Manifest, model: &str, out_dir: &Path, seed: u64) -> Result<Vec<String>> {
    let engine = Engine::load(manifest, model)?;
    let info = &engine.info;
    let batch = *engine.batch_sizes().last().unwrap();
    let n_show = batch.min(16);
    let img_size = engine.img_size().expect("latent model");
    let mut written = Vec::new();

    for (tag, method) in [("forecast", Method::Forecast { t_use: 1 }), ("fpi", Method::Fpi)] {
        let res = engine.sample_batch(method, batch, seed)?;
        let zs: Vec<Vec<i32>> = res.jobs[..n_show].iter().map(|j| j.x.clone()).collect();
        let imgs = engine.decode(&zs)?;
        // Decoded samples.
        let tiles: Vec<Image> = imgs
            .iter()
            .map(|im| {
                let rgb01: Vec<f32> = im.iter().map(|v| (v + 1.0) / 2.0).collect();
                Image::from_rgb_chw(img_size, img_size, &rgb01).upscale(3)
            })
            .collect();
        let path = out_dir.join(format!("{model}_{tag}_decoded.ppm"));
        Image::grid(&tiles, 4).write_ppm(&path)?;
        written.push(path.display().to_string());
        // Latent mistake maps (8x8, upscaled to image size like the paper).
        let tiles: Vec<Image> = res.jobs[..n_show]
            .iter()
            .map(|j| {
                let frac = trace::mistake_fractions(j, info.channels);
                let mut im = Image::new(info.width, info.height);
                im.overlay_mistakes(&frac);
                im.upscale(6)
            })
            .collect();
        let path = out_dir.join(format!("{model}_{tag}_latent_mistakes.ppm"));
        Image::grid(&tiles, 4).write_ppm(&path)?;
        written.push(path.display().to_string());
        println!("{model} {tag}: {} ARM calls ({:.1}%)", res.arm_calls, res.calls_pct(info.dim));
    }
    Ok(written)
}

/// Figure 6: convergence-iteration heatmaps (log colormap), FPI vs
/// baseline, averaged over a batch of 32 samples and all channels.
pub fn fig6(manifest: &Manifest, model: &str, out_dir: &Path, seed: u64) -> Result<Vec<String>> {
    let engine = Engine::load(manifest, model)?;
    let info = &engine.info;
    let batch = *engine.batch_sizes().last().unwrap();
    let mut written = Vec::new();

    let fpi = engine.sample_batch(Method::Fpi, batch, seed)?;
    let base = engine.sample_batch(Method::Baseline, batch, seed)?;
    let vmax = info.dim as f32;
    for (tag, res) in [("fpi", &fpi), ("baseline", &base)] {
        let map = trace::mean_convergence_map(&res.jobs, info.channels);
        let im = Image::from_heat_log(info.width, info.height, &map, vmax).upscale(8);
        let path = out_dir.join(format!("{model}_converge_{tag}.ppm"));
        im.write_ppm(&path)?;
        written.push(path.display().to_string());
        let mean_iter: f32 = map.iter().sum::<f32>() / map.len() as f32;
        println!("fig6 {tag}: mean convergence iteration {mean_iter:.1} (of d={})", info.dim);
    }
    println!(
        "fig6: fpi finished in {} passes vs baseline {} (batch of {batch})",
        fpi.arm_calls, base.arm_calls
    );
    Ok(written)
}
