//! Benchmark infrastructure: a criterion-lite [`harness`], the paper
//! table/figure regenerators ([`tables`], [`figures`]), and serving
//! workload generators ([`workload`]).
//!
//! Every table and figure of the paper's evaluation (§4) maps to a
//! function here; `cargo bench` and the `predsamp table1|table2|table3|
//! fig3..fig6` subcommands call the same code.

pub mod figures;
pub mod harness;
pub mod tables;
pub mod workload;
