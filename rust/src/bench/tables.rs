//! Regenerators for the paper's Tables 1-3.
//!
//! Each function prints rows in the paper's format — ARM calls as a
//! percentage of the d-call baseline, wall time, and speedup, as
//! mean ± Bessel-corrected std over seeded runs — and returns the raw
//! row data for programmatic checks. The paper uses seeds {0..9}; the
//! default here is 3 seeds on this single-core substrate (`--seeds 10`
//! restores the full protocol).

use crate::coordinator::config::Method;
use crate::coordinator::engine::Engine;
use crate::runtime::artifact::Manifest;
use crate::substrate::stats::Summary;
use anyhow::Result;

/// One printed table row.
#[derive(Clone, Debug)]
pub struct Row {
    pub model: String,
    pub method: String,
    pub batch: usize,
    pub calls_pct: Summary,
    pub secs: Summary,
    pub speedup: f64,
}

impl Row {
    fn print(&self) {
        println!(
            "| {:<16} | {:<16} | b{:<3} | {:>14} % | {:>14} s | {:>6.1}x |",
            self.model,
            self.method,
            self.batch,
            self.calls_pct.cell(1),
            self.secs.cell(2),
            self.speedup
        );
    }
}

fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "| {:<16} | {:<16} | {:<4} | {:>16} | {:>16} | {:>7} |",
        "model", "method", "B", "ARM calls", "time", "speedup"
    );
    println!("|{}|{}|{}|{}|{}|{}|", "-".repeat(18), "-".repeat(18), "-".repeat(6), "-".repeat(18), "-".repeat(18), "-".repeat(9));
}

/// Measure one (model, method, batch) cell over seeds.
pub fn measure_cell(engine: &Engine, method: Method, batch: usize, seeds: &[u64]) -> Result<(Summary, Summary)> {
    let mut pcts = Vec::with_capacity(seeds.len());
    let mut secs = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        let res = engine.sample_batch(method, batch, seed)?;
        pcts.push(res.calls_pct(engine.info.dim));
        secs.push(res.wall_secs);
    }
    Ok((Summary::of(&pcts), Summary::of(&secs)))
}

fn run_rows(
    manifest: &Manifest,
    title: &str,
    spec: &[(&str, Vec<Method>)],
    batches: &[usize],
    seeds: &[u64],
) -> Result<Vec<Row>> {
    header(title);
    let mut rows = Vec::new();
    for (model, methods) in spec {
        let engine = Engine::load(manifest, model)?;
        for &batch in batches {
            if !engine.batch_sizes().contains(&batch) {
                continue;
            }
            let mut base_mean = f64::NAN;
            for &method in methods {
                let (pct, secs) = measure_cell(&engine, method, batch, seeds)?;
                if method == Method::Baseline {
                    base_mean = secs.mean;
                }
                let row = Row {
                    model: model.to_string(),
                    method: method.label(),
                    batch,
                    calls_pct: pct,
                    secs,
                    speedup: if base_mean.is_finite() && secs.mean > 0.0 { base_mean / secs.mean } else { 1.0 },
                };
                row.print();
                rows.push(row);
            }
        }
    }
    Ok(rows)
}

/// Table 1 — explicit likelihood modeling (paper §4.1).
pub fn table1(manifest: &Manifest, seeds: &[u64], batches: &[usize], models: &[String]) -> Result<Vec<Row>> {
    let all: Vec<(&str, Vec<Method>)> = vec![
        (
            "mnist_bin",
            vec![Method::Baseline, Method::Zeros, Method::PredictLast, Method::Fpi, Method::Forecast { t_use: 20 }],
        ),
        ("svhn8", vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }]),
        ("cifar5", vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }]),
        (
            "cifar8",
            vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }, Method::Forecast { t_use: 5 }],
        ),
    ];
    let spec: Vec<_> = all
        .into_iter()
        .filter(|(m, _)| models.is_empty() || models.iter().any(|x| x == m))
        .collect();
    run_rows(manifest, "Table 1: predictive sampling, explicit likelihood models", &spec, batches, seeds)
}

/// Table 2 — ARMs over the autoencoder latent space (paper §4.2).
pub fn table2(manifest: &Manifest, seeds: &[u64], batches: &[usize], models: &[String]) -> Result<Vec<Row>> {
    let all: Vec<(&str, Vec<Method>)> = vec![
        ("latent_svhn", vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }]),
        ("latent_cifar", vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }]),
        ("latent_in32", vec![Method::Baseline, Method::Fpi, Method::Forecast { t_use: 1 }]),
    ];
    let spec: Vec<_> = all
        .into_iter()
        .filter(|(m, _)| models.is_empty() || models.iter().any(|x| x == m))
        .collect();
    run_rows(manifest, "Table 2: predictive sampling of latent variables", &spec, batches, seeds)
}

/// Table 3 — ablations on 8-bit CIFAR (paper §4.3): reparametrization and
/// representation sharing.
pub fn table3(manifest: &Manifest, seeds: &[u64]) -> Result<Vec<Row>> {
    header("Table 3: ablations (cifar8, batch 32)");
    let batch = 32;
    let mut rows = Vec::new();
    let engine = Engine::load(manifest, "cifar8")?;
    for method in [Method::Fpi, Method::NoReparam, Method::Forecast { t_use: 1 }] {
        let (pct, secs) = measure_cell(&engine, method, batch, seeds)?;
        let label = match method {
            Method::Fpi => "fpi".to_string(),
            Method::NoReparam => "fpi w/o reparam".to_string(),
            Method::Forecast { .. } => "forecast shared-h".to_string(),
            _ => unreachable!(),
        };
        let row = Row { model: "cifar8".into(), method: label, batch, calls_pct: pct, secs, speedup: 1.0 };
        row.print();
        rows.push(row);
    }
    // The no-representation-sharing variant is a separately trained model.
    let engine_ns = Engine::load(manifest, "cifar8_noshare")?;
    let (pct, secs) = measure_cell(&engine_ns, Method::Forecast { t_use: 1 }, batch, seeds)?;
    let row = Row {
        model: "cifar8".into(),
        method: "forecast w/o shared h".into(),
        batch,
        calls_pct: pct,
        secs,
        speedup: 1.0,
    };
    row.print();
    rows.push(row);
    Ok(rows)
}
