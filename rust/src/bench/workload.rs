//! Serving workload generation: request streams with Poisson arrivals for
//! the scheduler ablation and the serving demo.

use crate::substrate::rng::Rng;

/// One synthetic sample request.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkItem {
    /// Arrival offset from stream start, seconds.
    pub at_secs: f64,
    pub n: usize,
    pub seed: u64,
}

/// Poisson arrival stream: `rate` requests/second, each asking for
/// `n_range` samples.
pub fn poisson_stream(rng: &mut Rng, rate: f64, duration_secs: f64, n_range: (usize, usize)) -> Vec<WorkItem> {
    assert!(rate > 0.0);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut id = 0u64;
    loop {
        // exponential inter-arrival
        t += -rng.uniform_open0().ln() / rate;
        if t >= duration_secs {
            break;
        }
        let n = if n_range.1 > n_range.0 {
            n_range.0 + rng.below((n_range.1 - n_range.0) as u64 + 1) as usize
        } else {
            n_range.0
        };
        out.push(WorkItem { at_secs: t, n, seed: id });
        id += 1;
    }
    out
}

/// Deterministic closed-loop stream: `count` back-to-back requests.
pub fn closed_loop(count: usize, n: usize) -> Vec<WorkItem> {
    (0..count)
        .map(|i| WorkItem { at_secs: 0.0, n, seed: i as u64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut rng = Rng::new(0);
        let items = poisson_stream(&mut rng, 50.0, 10.0, (1, 4));
        let rate = items.len() as f64 / 10.0;
        assert!((rate - 50.0).abs() < 10.0, "rate {rate}");
        assert!(items.windows(2).all(|w| w[0].at_secs <= w[1].at_secs));
        assert!(items.iter().all(|i| (1..=4).contains(&i.n)));
    }

    #[test]
    fn closed_loop_items() {
        let items = closed_loop(5, 2);
        assert_eq!(items.len(), 5);
        assert!(items.iter().enumerate().all(|(i, it)| it.seed == i as u64 && it.n == 2));
    }
}
