//! Predictive sampling — the paper's Algorithm 1, batched.
//!
//! One `PredictiveSampler` owns B slots tied to a fixed-batch step
//! executable. Each ARM pass: (1) every active slot's input row is the
//! valid prefix `x_{<i}` plus policy forecasts for `[i, d)`; (2) a single
//! parallel inference pass produces log-probs for every position of every
//! slot; (3) per slot, the reparametrized outputs
//! `x'_j = argmax(logp_j + ε_j)` are scanned from the frontier — while the
//! forecast agrees with `x'_j` the frontier advances for free, and on the
//! first disagreement the (still valid) output is written and the pass
//! ends for that slot.
//!
//! Because ε is fixed per job, every policy produces *bitwise* the sample
//! ancestral sampling would produce with the same ε (tested below against
//! the mock ARM and, in `tests/integration.rs`, against the compiled
//! artifacts). Slots can be individually reset with a new job, which is
//! what the continuous-batching scheduler builds on.

use super::forecast::{ForecastCtx, Forecaster};
use super::noise::JobNoise;
use super::{BatchResult, JobResult, StepModel};
use crate::runtime::step::StepOutput;
use crate::substrate::gumbel::{argmax, gumbel_argmax};
use crate::substrate::timer::Timer;
use anyhow::{ensure, Result};

struct Slot {
    noise: JobNoise,
    frontier: usize,
    /// Reparametrized outputs of the previous pass (valid prefix + proposals).
    out_prev: Vec<i32>,
    /// Greedy outputs of the previous pass (no-reparametrization ablation).
    greedy_prev: Vec<i32>,
    first: bool,
    done: bool,
    /// Passes this slot participated in while active.
    iterations: usize,
    mistakes: Vec<u8>,
    converge_iter: Vec<u32>,
    occupied: bool,
}

impl Slot {
    fn fresh(noise: JobNoise, d: usize) -> Slot {
        Slot {
            noise,
            frontier: 0,
            out_prev: vec![0; d],
            greedy_prev: vec![0; d],
            first: true,
            done: false,
            iterations: 0,
            mistakes: vec![0; d],
            converge_iter: vec![0; d],
            occupied: true,
        }
    }
}

pub struct PredictiveSampler<'m, M: StepModel> {
    model: &'m M,
    forecaster: Box<dyn Forecaster>,
    slots: Vec<Option<Slot>>,
    /// `[B, d]` input rows; valid prefixes persist across passes.
    x: Vec<i32>,
    out: StepOutput,
    /// Total ARM passes run by this sampler.
    pub passes: usize,
}

impl<'m, M: StepModel> PredictiveSampler<'m, M> {
    pub fn new(model: &'m M, forecaster: Box<dyn Forecaster>) -> Self {
        let b = model.batch();
        let d = model.dim();
        PredictiveSampler {
            model,
            forecaster,
            slots: (0..b).map(|_| None).collect(),
            x: vec![0; b * d],
            out: StepOutput::default(),
            passes: 0,
        }
    }

    pub fn batch(&self) -> usize {
        self.model.batch()
    }

    /// Install a new job in `slot` (replacing any previous job).
    pub fn reset_slot(&mut self, slot: usize, noise: JobNoise) {
        let d = self.model.dim();
        assert_eq!(noise.dim, d, "noise dim");
        assert_eq!(noise.k, self.model.categories(), "noise k");
        self.slots[slot] = Some(Slot::fresh(noise, d));
        self.x[slot * d..(slot + 1) * d].fill(0);
    }

    /// Number of slots with an unconverged job.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().flatten().filter(|s| s.occupied && !s.done).count()
    }

    pub fn slot_done(&self, slot: usize) -> bool {
        self.slots[slot].as_ref().map(|s| s.done).unwrap_or(true)
    }

    /// Extract the finished job from `slot`, freeing it.
    pub fn take_result(&mut self, slot: usize) -> Option<JobResult> {
        let d = self.model.dim();
        let s = self.slots[slot].take()?;
        if !s.done {
            self.slots[slot] = Some(s);
            return None;
        }
        Some(JobResult {
            x: self.x[slot * d..(slot + 1) * d].to_vec(),
            iterations: s.iterations,
            mistakes: s.mistakes,
            converge_iter: s.converge_iter,
        })
    }

    /// One ARM pass over the whole batch (Algorithm 1's loop body).
    pub fn step(&mut self) -> Result<()> {
        let d = self.model.dim();
        let k = self.model.categories();
        let c = self.model.channels();
        let t_fore = self.model.t_fore();
        let pixels = self.model.pixels();
        ensure!(self.active_slots() > 0, "no active jobs");

        // (1) Build inputs: valid prefix + forecasts. Reads the *previous*
        // pass's outputs (self.out), so this must precede run_into.
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.done {
                continue;
            }
            let row = &mut self.x[si * d..(si + 1) * d];
            let fore_prev: &[f32] = if s.first || self.out.fore.is_empty() {
                &[]
            } else {
                let len = pixels * t_fore * k;
                &self.out.fore[si * len..(si + 1) * len]
            };
            let ctx = ForecastCtx {
                i: s.frontier,
                dim: d,
                channels: c,
                k,
                t_fore,
                pixels,
                out_prev: &s.out_prev,
                greedy_prev: &s.greedy_prev,
                fore_prev,
                noise: &s.noise,
                first: s.first,
            };
            self.forecaster.forecast(&ctx, row);
        }

        // (2) One parallel inference pass.
        self.model.run_into(&self.x, &mut self.out)?;
        self.passes += 1;

        // (3) Scan outputs per slot.
        let reparam = self.forecaster.reparametrized();
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.done {
                continue;
            }
            s.iterations += 1;
            s.first = false;
            if !reparam {
                // Ablation: fresh noise every pass.
                s.noise.redraw();
            }
            let row = &mut self.x[si * d..(si + 1) * d];
            let mut j = s.frontier;
            // Valid prefix of out_prev mirrors x.
            s.out_prev[..j].copy_from_slice(&row[..j]);
            s.greedy_prev[..j].copy_from_slice(&row[..j]);
            let mut advancing = true;
            while j < d {
                let lp = &self.out.logp[(si * d + j) * k..(si * d + j + 1) * k];
                let out_j = gumbel_argmax(lp, s.noise.row(j)) as i32;
                s.out_prev[j] = out_j;
                s.greedy_prev[j] = argmax(lp) as i32;
                if advancing {
                    if row[j] == out_j {
                        // Correct forecast: position finalized for free.
                        s.converge_iter[j] = s.iterations as u32;
                        j += 1;
                        s.frontier = j;
                    } else {
                        // First disagreement: out_j is still a valid sample
                        // (its conditioning is the valid prefix). Write it,
                        // mark the mistake, and stop advancing.
                        row[j] = out_j;
                        s.out_prev[j] = out_j;
                        s.mistakes[j] = 1;
                        s.converge_iter[j] = s.iterations as u32;
                        j += 1;
                        s.frontier = j;
                        advancing = false;
                    }
                } else {
                    j += 1;
                }
            }
            if s.frontier >= d {
                s.done = true;
            }
        }
        Ok(())
    }

    /// Fill every slot with jobs `(seed, job_id = slot index)`, run to
    /// convergence of the whole batch, and report the paper's batched
    /// metrics (slowest job determines `arm_calls`).
    pub fn run_sync(&mut self, seed: u64) -> Result<BatchResult> {
        self.run_sync_offset(seed, 0)
    }

    /// As [`Self::run_sync`], but slot `s` takes job id `job_offset + s`.
    /// Chunked serving uses this so consecutive chunks of one request draw
    /// independent noise blocks instead of repeating jobs `0..B`.
    pub fn run_sync_offset(&mut self, seed: u64, job_offset: u64) -> Result<BatchResult> {
        let b = self.model.batch();
        let d = self.model.dim();
        let k = self.model.categories();
        for slot in 0..b {
            self.reset_slot(slot, JobNoise::new(seed, job_offset + slot as u64, d, k));
        }
        self.passes = 0;
        let timer = Timer::start();
        // Strict triangular dependence guarantees convergence in <= d
        // passes; the +1 margin covers the all-correct final verification
        // pass of degenerate policies.
        for _ in 0..=d {
            self.step()?;
            if (0..b).all(|s| self.slot_done(s)) {
                break;
            }
        }
        let wall = timer.secs();
        let jobs: Vec<JobResult> = (0..b)
            .map(|s| self.take_result(s).expect("job converged"))
            .collect();
        ensure!(jobs.iter().all(|j| j.x.len() == d), "incomplete jobs");
        Ok(BatchResult { jobs, arm_calls: self.passes, wall_secs: wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ancestral::ancestral_sample;
    use crate::sampler::forecast;
    use crate::sampler::mock::MockArm;
    use crate::substrate::proptest_lite::check;
    use crate::{prop_assert, prop_assert_eq};

    fn policies() -> Vec<Box<dyn Forecaster>> {
        vec![
            Box::new(forecast::Zeros),
            Box::new(forecast::PredictLast),
            Box::new(forecast::FpiReuse),
            Box::new(forecast::Learned { t_use: 2 }),
        ]
    }

    #[test]
    fn exactness_property_all_policies() {
        // THE paper guarantee: same ε ⇒ every predictive policy returns
        // bitwise the ancestral sample.
        check("predictive-exactness", 12, |g| {
            let c = g.usize_in(1, 4);
            let pixels = g.usize_in(2, 7);
            let k = g.usize_in(2, 7);
            let strength = g.f64_in(0.0, 4.0) as f32;
            let model = MockArm::new(1, c, pixels, k, 2, strength, g.rng.next_u64());
            let seed = g.rng.next_u64();
            let d = model.dim();
            let reference = ancestral_sample(&model, &JobNoise::new(seed, 0, d, k)).unwrap();
            for fc in policies() {
                let name = fc.name();
                let mut ps = PredictiveSampler::new(&model, fc);
                ps.reset_slot(0, JobNoise::new(seed, 0, d, k));
                for _ in 0..=d {
                    ps.step().map_err(|e| e.to_string())?;
                    if ps.slot_done(0) {
                        break;
                    }
                }
                let r = ps.take_result(0).ok_or("did not converge")?;
                prop_assert_eq!(&r.x, &reference.x, "policy {} diverged from ancestral", name);
                prop_assert!(r.iterations <= d, "policy {}: {} > d={}", name, r.iterations, d);
            }
            Ok(())
        });
    }

    #[test]
    fn batched_equals_single() {
        // Job noise is keyed by job id, so the same job sampled in any
        // batch slot yields the same sample.
        let model1 = MockArm::new(1, 3, 5, 4, 2, 2.0, 9);
        let model4 = MockArm::new(4, 3, 5, 4, 2, 2.0, 9);
        let d = model1.dim();
        let mut singles = Vec::new();
        for id in 0..4u64 {
            let mut ps = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(42, id, d, 4));
            while !ps.slot_done(0) {
                ps.step().unwrap();
            }
            singles.push(ps.take_result(0).unwrap().x);
        }
        let mut ps = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let batch = ps.run_sync(42).unwrap();
        for (id, job) in batch.jobs.iter().enumerate() {
            assert_eq!(job.x, singles[id], "slot {id}");
        }
    }

    #[test]
    fn run_sync_offset_matches_per_job_reference() {
        // run_sync_offset(seed, o) slot s must equal job id o+s sampled
        // alone — the chunked serving path's correctness contract.
        let model1 = MockArm::new(1, 3, 5, 4, 2, 2.0, 9);
        let model4 = MockArm::new(4, 3, 5, 4, 2, 2.0, 9);
        let d = model1.dim();
        let offset = 4u64;
        let mut ps = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let chunk = ps.run_sync_offset(42, offset).unwrap();
        for s in 0..4u64 {
            let mut ps1 = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
            ps1.reset_slot(0, JobNoise::new(42, offset + s, d, 4));
            while !ps1.slot_done(0) {
                ps1.step().unwrap();
            }
            let single = ps1.take_result(0).unwrap().x;
            assert_eq!(chunk.jobs[s as usize].x, single, "job {}", offset + s);
        }
        // And the offset chunk is disjoint from the offset-0 chunk.
        let mut ps0 = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let chunk0 = ps0.run_sync_offset(42, 0).unwrap();
        for s in 0..4 {
            assert_ne!(chunk.jobs[s].x, chunk0.jobs[s].x, "slot {s} repeated noise across chunks");
        }
    }

    #[test]
    fn converge_iter_and_mistakes_consistent() {
        check("trace-consistency", 10, |g| {
            let model = MockArm::new(1, 2, g.usize_in(2, 6), g.usize_in(2, 5), 2, 2.5, g.rng.next_u64());
            let d = model.dim();
            let mut ps = PredictiveSampler::new(&model, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(g.rng.next_u64(), 0, d, model.categories()));
            while !ps.slot_done(0) {
                ps.step().map_err(|e| e.to_string())?;
            }
            let r = ps.take_result(0).unwrap();
            // every variable finalized at some pass in [1, iterations]
            prop_assert!(
                r.converge_iter.iter().all(|&it| it >= 1 && it as usize <= r.iterations),
                "converge_iter out of range"
            );
            // converge passes are non-decreasing along the sequence
            prop_assert!(
                r.converge_iter.windows(2).all(|w| w[0] <= w[1]),
                "convergence must be monotone in raster order: {:?}",
                r.converge_iter
            );
            // number of mistakes equals iterations-adjacent rejections and
            // is bounded by iterations (at most one mistake per pass).
            let n_mist: usize = r.mistakes.iter().map(|&m| m as usize).sum();
            prop_assert!(n_mist <= r.iterations, "mistakes {} > iters {}", n_mist, r.iterations);
            Ok(())
        });
    }

    #[test]
    fn weak_model_converges_fast_strong_model_slow() {
        let weak = MockArm::new(1, 3, 8, 4, 1, 0.1, 5);
        let strong = MockArm::new(1, 3, 8, 4, 1, 8.0, 5);
        let d = weak.dim();
        let iters = |m: &MockArm| {
            let mut ps = PredictiveSampler::new(m, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(3, 0, d, 4));
            while !ps.slot_done(0) {
                ps.step().unwrap();
            }
            ps.take_result(0).unwrap().iterations
        };
        assert!(iters(&weak) <= iters(&strong), "coupling should slow FPI");
        assert!(iters(&weak) < d / 2, "near-iid model should converge quickly");
    }

    #[test]
    fn noreparam_still_valid_but_slow() {
        // The ablation must still produce a valid model sample (all values
        // in range, convergence <= d) even though noise is redrawn.
        let model = MockArm::new(1, 3, 6, 5, 1, 3.0, 11);
        let d = model.dim();
        let mut ps = PredictiveSampler::new(&model, Box::new(forecast::NoReparam));
        ps.reset_slot(0, JobNoise::new(8, 0, d, 5));
        for _ in 0..=d {
            ps.step().unwrap();
            if ps.slot_done(0) {
                break;
            }
        }
        let r = ps.take_result(0).unwrap();
        assert!(r.x.iter().all(|&v| v >= 0 && v < 5));
        assert!(r.iterations <= d);
    }

    #[test]
    fn slot_refill_mid_batch() {
        // Finishing one slot and installing a new job must not disturb the
        // other slots' samples (scheduler invariant).
        let model = MockArm::new(2, 2, 5, 4, 1, 2.0, 13);
        let d = model.dim();
        let k = 4;
        // Reference: job 7 sampled alone.
        let model1 = MockArm::new(1, 2, 5, 4, 1, 2.0, 13);
        let mut ps1 = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
        ps1.reset_slot(0, JobNoise::new(1, 7, d, k));
        while !ps1.slot_done(0) {
            ps1.step().unwrap();
        }
        let ref7 = ps1.take_result(0).unwrap().x;

        let mut ps = PredictiveSampler::new(&model, Box::new(forecast::FpiReuse));
        ps.reset_slot(0, JobNoise::new(1, 0, d, k));
        ps.reset_slot(1, JobNoise::new(1, 7, d, k));
        // step until slot 1 finishes; then refill slot 1 with job 9.
        while !ps.slot_done(1) {
            ps.step().unwrap();
        }
        let got7 = ps.take_result(1).unwrap().x;
        assert_eq!(got7, ref7, "slot placement must not change the sample");
        ps.reset_slot(1, JobNoise::new(1, 9, d, k));
        while !ps.slot_done(0) || !ps.slot_done(1) {
            ps.step().unwrap();
        }
        assert!(ps.take_result(0).is_some());
        assert!(ps.take_result(1).is_some());
    }
}
