//! Predictive sampling — the paper's Algorithm 1, batched.
//!
//! One `PredictiveSampler` owns B slots tied to a fixed-batch step
//! executable. Each ARM pass: (1) every active slot's input row is the
//! valid prefix `x_{<i}` plus policy forecasts for `[i, d)`; (2) a single
//! parallel inference pass produces log-probs for every position of every
//! slot; (3) per slot, the reparametrized outputs
//! `x'_j = argmax(logp_j + ε_j)` are scanned from the frontier — while the
//! forecast agrees with `x'_j` the frontier advances for free, and on the
//! first disagreement the (still valid) output is written and the pass
//! ends for that slot.
//!
//! Because ε is fixed per job, every policy produces *bitwise* the sample
//! ancestral sampling would produce with the same ε (tested below against
//! the mock ARM and, in `tests/integration.rs`, against the compiled
//! artifacts). Slots can be individually reset with a new job, which is
//! what the continuous-batching scheduler builds on.
//!
//! Each pass the sampler derives a [`PassPlan`] from slot state (dead
//! slots, per-slot frontiers) and the policy's capability flags, so a
//! plan-aware backend only computes the positions that will actually be
//! read; `positions_evaluated` accumulates that useful-work metric. Slots
//! can also be *migrated* between samplers of different batch sizes
//! ([`PredictiveSampler::extract_slot`] / [`PredictiveSampler::install_slot`]),
//! which is what the scheduler's batch down-shifting builds on — noise is
//! keyed by job id, never by slot, so placement is provably irrelevant.
#![deny(missing_docs)]

use super::forecast::{ForecastCtx, Forecaster};
use super::noise::JobNoise;
use super::{BatchResult, JobResult, PassPlan, SlotSpan, StepModel};
use crate::runtime::step::StepOutput;
use crate::substrate::gumbel::{argmax, gumbel_argmax};
use crate::substrate::timer::Timer;
use anyhow::{ensure, Result};

struct Slot {
    noise: JobNoise,
    frontier: usize,
    /// Reparametrized outputs of the previous pass (valid prefix + proposals).
    out_prev: Vec<i32>,
    /// Greedy outputs of the previous pass (no-reparametrization ablation).
    greedy_prev: Vec<i32>,
    first: bool,
    done: bool,
    /// Passes this slot participated in while active.
    iterations: usize,
    mistakes: Vec<u8>,
    converge_iter: Vec<u32>,
}

impl Slot {
    fn fresh(noise: JobNoise, d: usize) -> Slot {
        Slot {
            noise,
            frontier: 0,
            out_prev: vec![0; d],
            greedy_prev: vec![0; d],
            first: true,
            done: false,
            iterations: 0,
            mistakes: vec![0; d],
            converge_iter: vec![0; d],
        }
    }
}

/// A mid-flight job lifted out of one sampler for installation in another
/// (batch down-shifting). Carries everything a pass depends on: the slot's
/// bookkeeping, its input row (valid prefix + last forecasts), and its
/// previous-pass forecast-head block (read by the learned policy).
pub struct SlotState {
    slot: Slot,
    x_row: Vec<i32>,
    fore_row: Vec<f32>,
}

impl SlotState {
    /// Whether the job has converged (its result is ready to take).
    pub fn done(&self) -> bool {
        self.slot.done
    }
}

/// The paper's Algorithm 1, batched: B slots of predictive sampling
/// against one fixed-batch [`StepModel`], generic over a [`Forecaster`]
/// policy. See the module docs for the pass anatomy and the exactness
/// and migration invariants everything above this layer builds on.
pub struct PredictiveSampler<'m, M: StepModel> {
    model: &'m M,
    forecaster: Box<dyn Forecaster>,
    slots: Vec<Option<Slot>>,
    /// `[B, d]` input rows; valid prefixes persist across passes.
    x: Vec<i32>,
    out: StepOutput,
    /// Reusable pass plan (rebuilt each step, no allocation steady-state).
    plan: PassPlan,
    /// When false, every pass runs the full `[B, d]` shape (`run_into`)
    /// instead of the frontier-aware plan — the pre-plan behavior, kept
    /// for the hot-path bench's full-vs-plan comparison.
    use_plan: bool,
    /// Total ARM passes run by this sampler.
    pub passes: usize,
    /// Output rows requested from the backend across all passes: log-prob
    /// positions plus forecast-head rows (`B * (d + P*T)` per full pass;
    /// the plan's live spans per planned pass) — the useful-work metric
    /// `benches/sampler_hotpath.rs` records.
    pub positions_evaluated: usize,
}

impl<'m, M: StepModel> PredictiveSampler<'m, M> {
    /// A sampler over `model`'s batch slots, all initially empty, driving
    /// forecasts through `forecaster`.
    pub fn new(model: &'m M, forecaster: Box<dyn Forecaster>) -> Self {
        let b = model.batch();
        let d = model.dim();
        PredictiveSampler {
            model,
            forecaster,
            slots: (0..b).map(|_| None).collect(),
            x: vec![0; b * d],
            out: StepOutput::default(),
            plan: PassPlan::default(),
            use_plan: true,
            passes: 0,
            positions_evaluated: 0,
        }
    }

    /// The model's batch size (number of slots).
    pub fn batch(&self) -> usize {
        self.model.batch()
    }

    /// Toggle frontier-aware passes (default on). With `false` every pass
    /// computes the full `[B, d]` shape — results are bitwise identical,
    /// only the work differs (property-tested in `tests/sampler_props.rs`).
    pub fn set_plan_mode(&mut self, use_plan: bool) {
        self.use_plan = use_plan;
    }

    /// Install a new job in `slot` (replacing any previous job).
    pub fn reset_slot(&mut self, slot: usize, noise: JobNoise) {
        let d = self.model.dim();
        assert_eq!(noise.dim, d, "noise dim");
        assert_eq!(noise.k, self.model.categories(), "noise k");
        self.slots[slot] = Some(Slot::fresh(noise, d));
        self.x[slot * d..(slot + 1) * d].fill(0);
    }

    /// Empty `slot` (no job; the pass plan marks the row dead).
    pub fn clear_slot(&mut self, slot: usize) {
        self.slots[slot] = None;
    }

    /// Admit a new job into the first free slot of a *running* sampler,
    /// returning the slot it landed in (`None` when every slot holds a
    /// job). The elastic scheduler's admission path: noise is keyed by
    /// job id, never by slot, so mid-schedule admission cannot disturb
    /// any neighbour's sample.
    pub fn admit(&mut self, noise: JobNoise) -> Option<usize> {
        let free = self.slots.iter().position(|s| s.is_none())?;
        self.reset_slot(free, noise);
        Some(free)
    }

    /// Number of slots with an unconverged job.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().flatten().filter(|s| !s.done).count()
    }

    /// Whether `slot` holds no unconverged job (empty slots count as done).
    pub fn slot_done(&self, slot: usize) -> bool {
        self.slots[slot].as_ref().map(|s| s.done).unwrap_or(true)
    }

    /// Lift the job out of `slot` for migration to another sampler
    /// (typically one with a smaller batch). The slot is left empty.
    pub fn extract_slot(&mut self, slot: usize) -> Option<SlotState> {
        let d = self.model.dim();
        let s = self.slots[slot].take()?;
        let x_row = self.x[slot * d..(slot + 1) * d].to_vec();
        // The forecast-head block travels only when the policy reads it
        // (models in a down-shift family may disagree on t_fore when the
        // heads are unread — logp-only variants export t_fore = 0).
        let len = self.model.pixels() * self.model.t_fore() * self.model.categories();
        let fore_row = if s.first || self.out.fore.is_empty() || !self.forecaster.reads_fore() {
            Vec::new()
        } else {
            self.out.fore[slot * len..(slot + 1) * len].to_vec()
        };
        Some(SlotState { slot: s, x_row, fore_row })
    }

    /// Install a migrated job in `slot` (replacing any previous job). The
    /// job resumes exactly where it left off: same frontier, same previous
    /// outputs, same noise — so the sample (and even the per-job pass
    /// count) is bitwise independent of the migration.
    pub fn install_slot(&mut self, slot: usize, st: SlotState) {
        let d = self.model.dim();
        assert_eq!(st.x_row.len(), d, "slot migrated across incompatible models");
        self.x[slot * d..(slot + 1) * d].copy_from_slice(&st.x_row);
        if !st.fore_row.is_empty() {
            let len = self.model.pixels() * self.model.t_fore() * self.model.categories();
            assert_eq!(st.fore_row.len(), len, "fore block migrated across incompatible models");
            let full = self.model.batch() * len;
            if self.out.fore.len() != full {
                self.out.fore.resize(full, 0.0);
            }
            self.out.fore[slot * len..(slot + 1) * len].copy_from_slice(&st.fore_row);
        }
        self.slots[slot] = Some(st.slot);
    }

    /// Tear the sampler down, recovering the forecaster for reuse in a
    /// successor sampler (batch down-shifting migrates the policy too).
    pub fn into_forecaster(self) -> Box<dyn Forecaster> {
        self.forecaster
    }

    /// Extract the finished job from `slot`, freeing it.
    pub fn take_result(&mut self, slot: usize) -> Option<JobResult> {
        let d = self.model.dim();
        let s = self.slots[slot].take()?;
        if !s.done {
            self.slots[slot] = Some(s);
            return None;
        }
        Some(JobResult {
            x: self.x[slot * d..(slot + 1) * d].to_vec(),
            iterations: s.iterations,
            mistakes: s.mistakes,
            converge_iter: s.converge_iter,
        })
    }

    /// One ARM pass over the whole batch (Algorithm 1's loop body).
    pub fn step(&mut self) -> Result<()> {
        let d = self.model.dim();
        let k = self.model.categories();
        let c = self.model.channels();
        let t_fore = self.model.t_fore();
        let pixels = self.model.pixels();
        ensure!(self.active_slots() > 0, "no active jobs");

        // (1) Build inputs: valid prefix + forecasts. Reads the *previous*
        // pass's outputs (self.out), so this must precede run_into.
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.done {
                continue;
            }
            let row = &mut self.x[si * d..(si + 1) * d];
            let fore_prev: &[f32] = if s.first || self.out.fore.is_empty() {
                &[]
            } else {
                let len = pixels * t_fore * k;
                &self.out.fore[si * len..(si + 1) * len]
            };
            let ctx = ForecastCtx {
                i: s.frontier,
                dim: d,
                channels: c,
                k,
                t_fore,
                pixels,
                out_prev: &s.out_prev,
                greedy_prev: &s.greedy_prev,
                fore_prev,
                noise: &s.noise,
                first: s.first,
            };
            self.forecaster.forecast(&ctx, row);
        }

        // (2) One parallel inference pass, restricted to the live spans:
        // dead slots are skipped, each active slot starts at its frontier,
        // and the forecast heads are skipped when no policy reads them.
        let need_full_scan = self.forecaster.reads_prev_tail();
        if self.use_plan {
            self.plan.need_fore = self.forecaster.reads_fore();
            self.plan.need_full_scan = need_full_scan;
            self.plan.slots.clear();
            for slot in &self.slots {
                self.plan.slots.push(match slot {
                    Some(s) if !s.done => SlotSpan { active: true, lo: s.frontier, hi: d },
                    _ => SlotSpan::default(),
                });
            }
            // `run_plan` reports what the backend really computed — the
            // plan's rows for a fully plan-exploiting backend, the chosen
            // variant's device cost for a shape catalog, the whole tensor
            // for a full-shape fallback.
            self.positions_evaluated += self.model.run_plan(&self.x, &mut self.out, &self.plan)?;
        } else {
            self.model.run_into(&self.x, &mut self.out)?;
            self.positions_evaluated += self.model.batch() * (d + pixels * t_fore);
        }
        self.passes += 1;

        // (3) Scan outputs per slot. Full mode keeps the full scan so the
        // bench's full-vs-plan comparison measures the pre-plan hot path.
        let early_stop = self.use_plan && !need_full_scan;
        let reparam = self.forecaster.reparametrized();
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.done {
                continue;
            }
            s.iterations += 1;
            s.first = false;
            if !reparam {
                // Ablation: fresh noise every pass.
                s.noise.redraw();
            }
            let row = &mut self.x[si * d..(si + 1) * d];
            let mut j = s.frontier;
            // Valid prefix of out_prev mirrors x.
            s.out_prev[..j].copy_from_slice(&row[..j]);
            s.greedy_prev[..j].copy_from_slice(&row[..j]);
            let mut advancing = true;
            while j < d {
                // Past the first disagreement the loop only materializes
                // out_prev/greedy_prev proposals for the next forecast —
                // skip that tail when the policy never reads it.
                if !advancing && early_stop {
                    break;
                }
                let lp = &self.out.logp[(si * d + j) * k..(si * d + j + 1) * k];
                let out_j = gumbel_argmax(lp, s.noise.row(j)) as i32;
                s.out_prev[j] = out_j;
                s.greedy_prev[j] = argmax(lp) as i32;
                if advancing {
                    if row[j] == out_j {
                        // Correct forecast: position finalized for free.
                        s.converge_iter[j] = s.iterations as u32;
                        j += 1;
                        s.frontier = j;
                    } else {
                        // First disagreement: out_j is still a valid sample
                        // (its conditioning is the valid prefix). Write it,
                        // mark the mistake, and stop advancing.
                        row[j] = out_j;
                        s.out_prev[j] = out_j;
                        s.mistakes[j] = 1;
                        s.converge_iter[j] = s.iterations as u32;
                        j += 1;
                        s.frontier = j;
                        advancing = false;
                    }
                } else {
                    j += 1;
                }
            }
            if s.frontier >= d {
                s.done = true;
            }
        }
        Ok(())
    }

    /// Fill every slot with jobs `(seed, job_id = slot index)`, run to
    /// convergence of the whole batch, and report the paper's batched
    /// metrics (slowest job determines `arm_calls`).
    pub fn run_sync(&mut self, seed: u64) -> Result<BatchResult> {
        self.run_sync_offset(seed, 0)
    }

    /// As [`Self::run_sync`], but slot `s` takes job id `job_offset + s`.
    /// Chunked serving uses this so consecutive chunks of one request draw
    /// independent noise blocks instead of repeating jobs `0..B`.
    pub fn run_sync_offset(&mut self, seed: u64, job_offset: u64) -> Result<BatchResult> {
        let b = self.model.batch();
        let d = self.model.dim();
        let k = self.model.categories();
        for slot in 0..b {
            self.reset_slot(slot, JobNoise::new(seed, job_offset + slot as u64, d, k));
        }
        self.passes = 0;
        self.positions_evaluated = 0;
        let timer = Timer::start();
        // Strict triangular dependence guarantees convergence in <= d
        // passes; the +1 margin covers the all-correct final verification
        // pass of degenerate policies.
        for _ in 0..=d {
            self.step()?;
            if (0..b).all(|s| self.slot_done(s)) {
                break;
            }
        }
        let wall = timer.secs();
        let jobs: Vec<JobResult> = (0..b)
            .map(|s| self.take_result(s).expect("job converged"))
            .collect();
        ensure!(jobs.iter().all(|j| j.x.len() == d), "incomplete jobs");
        Ok(BatchResult { jobs, arm_calls: self.passes, wall_secs: wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ancestral::ancestral_sample;
    use crate::sampler::forecast;
    use crate::sampler::mock::MockArm;
    use crate::substrate::proptest_lite::check;
    use crate::{prop_assert, prop_assert_eq};

    fn policies() -> Vec<Box<dyn Forecaster>> {
        vec![
            Box::new(forecast::Zeros),
            Box::new(forecast::PredictLast),
            Box::new(forecast::FpiReuse),
            Box::new(forecast::Learned { t_use: 2 }),
        ]
    }

    #[test]
    fn exactness_property_all_policies() {
        // THE paper guarantee: same ε ⇒ every predictive policy returns
        // bitwise the ancestral sample.
        check("predictive-exactness", 12, |g| {
            let c = g.usize_in(1, 4);
            let pixels = g.usize_in(2, 7);
            let k = g.usize_in(2, 7);
            let strength = g.f64_in(0.0, 4.0) as f32;
            let model = MockArm::new(1, c, pixels, k, 2, strength, g.rng.next_u64());
            let seed = g.rng.next_u64();
            let d = model.dim();
            let reference = ancestral_sample(&model, &JobNoise::new(seed, 0, d, k)).unwrap();
            for fc in policies() {
                let name = fc.name();
                let mut ps = PredictiveSampler::new(&model, fc);
                ps.reset_slot(0, JobNoise::new(seed, 0, d, k));
                for _ in 0..=d {
                    ps.step().map_err(|e| e.to_string())?;
                    if ps.slot_done(0) {
                        break;
                    }
                }
                let r = ps.take_result(0).ok_or("did not converge")?;
                prop_assert_eq!(&r.x, &reference.x, "policy {} diverged from ancestral", name);
                prop_assert!(r.iterations <= d, "policy {}: {} > d={}", name, r.iterations, d);
            }
            Ok(())
        });
    }

    #[test]
    fn batched_equals_single() {
        // Job noise is keyed by job id, so the same job sampled in any
        // batch slot yields the same sample.
        let model1 = MockArm::new(1, 3, 5, 4, 2, 2.0, 9);
        let model4 = MockArm::new(4, 3, 5, 4, 2, 2.0, 9);
        let d = model1.dim();
        let mut singles = Vec::new();
        for id in 0..4u64 {
            let mut ps = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(42, id, d, 4));
            while !ps.slot_done(0) {
                ps.step().unwrap();
            }
            singles.push(ps.take_result(0).unwrap().x);
        }
        let mut ps = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let batch = ps.run_sync(42).unwrap();
        for (id, job) in batch.jobs.iter().enumerate() {
            assert_eq!(job.x, singles[id], "slot {id}");
        }
    }

    #[test]
    fn run_sync_offset_matches_per_job_reference() {
        // run_sync_offset(seed, o) slot s must equal job id o+s sampled
        // alone — the chunked serving path's correctness contract.
        let model1 = MockArm::new(1, 3, 5, 4, 2, 2.0, 9);
        let model4 = MockArm::new(4, 3, 5, 4, 2, 2.0, 9);
        let d = model1.dim();
        let offset = 4u64;
        let mut ps = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let chunk = ps.run_sync_offset(42, offset).unwrap();
        for s in 0..4u64 {
            let mut ps1 = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
            ps1.reset_slot(0, JobNoise::new(42, offset + s, d, 4));
            while !ps1.slot_done(0) {
                ps1.step().unwrap();
            }
            let single = ps1.take_result(0).unwrap().x;
            assert_eq!(chunk.jobs[s as usize].x, single, "job {}", offset + s);
        }
        // And the offset chunk is disjoint from the offset-0 chunk.
        let mut ps0 = PredictiveSampler::new(&model4, Box::new(forecast::FpiReuse));
        let chunk0 = ps0.run_sync_offset(42, 0).unwrap();
        for s in 0..4 {
            assert_ne!(chunk.jobs[s].x, chunk0.jobs[s].x, "slot {s} repeated noise across chunks");
        }
    }

    #[test]
    fn converge_iter_and_mistakes_consistent() {
        check("trace-consistency", 10, |g| {
            let model = MockArm::new(1, 2, g.usize_in(2, 6), g.usize_in(2, 5), 2, 2.5, g.rng.next_u64());
            let d = model.dim();
            let mut ps = PredictiveSampler::new(&model, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(g.rng.next_u64(), 0, d, model.categories()));
            while !ps.slot_done(0) {
                ps.step().map_err(|e| e.to_string())?;
            }
            let r = ps.take_result(0).unwrap();
            // every variable finalized at some pass in [1, iterations]
            prop_assert!(
                r.converge_iter.iter().all(|&it| it >= 1 && it as usize <= r.iterations),
                "converge_iter out of range"
            );
            // converge passes are non-decreasing along the sequence
            prop_assert!(
                r.converge_iter.windows(2).all(|w| w[0] <= w[1]),
                "convergence must be monotone in raster order: {:?}",
                r.converge_iter
            );
            // number of mistakes equals iterations-adjacent rejections and
            // is bounded by iterations (at most one mistake per pass).
            let n_mist: usize = r.mistakes.iter().map(|&m| m as usize).sum();
            prop_assert!(n_mist <= r.iterations, "mistakes {} > iters {}", n_mist, r.iterations);
            Ok(())
        });
    }

    #[test]
    fn weak_model_converges_fast_strong_model_slow() {
        let weak = MockArm::new(1, 3, 8, 4, 1, 0.1, 5);
        let strong = MockArm::new(1, 3, 8, 4, 1, 8.0, 5);
        let d = weak.dim();
        let iters = |m: &MockArm| {
            let mut ps = PredictiveSampler::new(m, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(3, 0, d, 4));
            while !ps.slot_done(0) {
                ps.step().unwrap();
            }
            ps.take_result(0).unwrap().iterations
        };
        assert!(iters(&weak) <= iters(&strong), "coupling should slow FPI");
        assert!(iters(&weak) < d / 2, "near-iid model should converge quickly");
    }

    #[test]
    fn noreparam_still_valid_but_slow() {
        // The ablation must still produce a valid model sample (all values
        // in range, convergence <= d) even though noise is redrawn.
        let model = MockArm::new(1, 3, 6, 5, 1, 3.0, 11);
        let d = model.dim();
        let mut ps = PredictiveSampler::new(&model, Box::new(forecast::NoReparam));
        ps.reset_slot(0, JobNoise::new(8, 0, d, 5));
        for _ in 0..=d {
            ps.step().unwrap();
            if ps.slot_done(0) {
                break;
            }
        }
        let r = ps.take_result(0).unwrap();
        assert!(r.x.iter().all(|&v| v >= 0 && v < 5));
        assert!(r.iterations <= d);
    }

    #[test]
    fn plan_mode_smoke_matches_full_mode() {
        // Quick in-crate smoke: frontier-aware passes are bitwise
        // invisible and do less work. The exhaustive per-policy /
        // per-regime property lives in `tests/sampler_props.rs`
        // (`plan-vs-full`).
        let model = MockArm::new(3, 2, 6, 4, 2, 2.5, 19);
        let run = |use_plan: bool| {
            let mut ps = PredictiveSampler::new(&model, Box::new(forecast::FpiReuse));
            ps.set_plan_mode(use_plan);
            let res = ps.run_sync(7).unwrap();
            (res, ps.positions_evaluated)
        };
        let (full, full_pos) = run(false);
        let (plan, plan_pos) = run(true);
        for s in 0..3 {
            assert_eq!(plan.jobs[s].x, full.jobs[s].x, "slot {s} sample");
        }
        assert_eq!(plan.arm_calls, full.arm_calls, "pass count");
        assert!(plan_pos < full_pos, "plan must shed work ({plan_pos} vs {full_pos})");
    }

    #[test]
    fn slot_migration_resumes_mid_job() {
        // extract_slot/install_slot must carry a mid-flight job across
        // samplers (and batch sizes) without changing its sample, trace,
        // or even its pass count — the down-shifting invariant.
        let m2 = MockArm::new(2, 3, 6, 5, 2, 3.0, 23);
        let m1 = MockArm { batch: 1, ..m2.clone() };
        let d = m2.dim();
        let k = m2.categories();
        for policy in ["fpi", "learned"] {
            // Reference: job 1 sampled alone to convergence.
            let mut ps1 = PredictiveSampler::new(&m1, crate::sampler::forecast::by_name(policy, 2).unwrap());
            ps1.reset_slot(0, JobNoise::new(5, 1, d, k));
            while !ps1.slot_done(0) {
                ps1.step().unwrap();
            }
            let reference = ps1.take_result(0).unwrap();

            // Run jobs 0 and 1 together for two passes, then migrate job 1
            // to a fresh batch-1 sampler mid-flight.
            let mut ps = PredictiveSampler::new(&m2, crate::sampler::forecast::by_name(policy, 2).unwrap());
            ps.reset_slot(0, JobNoise::new(5, 0, d, k));
            ps.reset_slot(1, JobNoise::new(5, 1, d, k));
            let mut migrated_passes = 0usize;
            while migrated_passes < 2 && !ps.slot_done(1) {
                ps.step().unwrap();
                migrated_passes += 1;
            }
            let st = ps.extract_slot(1).expect("slot 1 in flight");
            let fc = ps.into_forecaster();
            let mut small = PredictiveSampler::new(&m1, fc);
            small.install_slot(0, st);
            while !small.slot_done(0) {
                small.step().unwrap();
                migrated_passes += 1;
            }
            let migrated = small.take_result(0).unwrap();
            assert_eq!(migrated.x, reference.x, "policy {policy}: migration changed the sample");
            assert_eq!(migrated.iterations, reference.iterations, "policy {policy}: migration changed pass count");
            assert_eq!(migrated.mistakes, reference.mistakes, "policy {policy}: migration changed mistakes");
            assert_eq!(migrated.converge_iter, reference.converge_iter, "policy {policy}: migration changed trace");
        }
    }

    #[test]
    fn admit_into_running_sampler_is_exact() {
        // Admission mid-schedule: a job admitted into a free slot of a
        // sampler that has already run passes must sample exactly as if
        // it ran alone, without disturbing the in-flight neighbour — and
        // admission must report the slot it used (None when full).
        let m = MockArm::new(2, 2, 5, 4, 1, 2.0, 13);
        let m1 = MockArm { batch: 1, ..m.clone() };
        let d = m.dim();
        let reference = |id: u64| {
            let mut ps = PredictiveSampler::new(&m1, Box::new(forecast::FpiReuse));
            ps.reset_slot(0, JobNoise::new(1, id, d, 4));
            while !ps.slot_done(0) {
                ps.step().unwrap();
            }
            ps.take_result(0).unwrap().x
        };
        let mut ps = PredictiveSampler::new(&m, Box::new(forecast::FpiReuse));
        ps.reset_slot(0, JobNoise::new(1, 0, d, 4));
        ps.step().unwrap();
        assert_eq!(ps.admit(JobNoise::new(1, 7, d, 4)), Some(1), "slot 1 is free");
        assert_eq!(ps.admit(JobNoise::new(1, 9, d, 4)), None, "sampler is full");
        while !ps.slot_done(0) || !ps.slot_done(1) {
            ps.step().unwrap();
        }
        assert_eq!(ps.take_result(0).unwrap().x, reference(0), "neighbour disturbed by admission");
        assert_eq!(ps.take_result(1).unwrap().x, reference(7), "admitted job diverged");
    }

    #[test]
    fn slot_refill_mid_batch() {
        // Finishing one slot and installing a new job must not disturb the
        // other slots' samples (scheduler invariant).
        let model = MockArm::new(2, 2, 5, 4, 1, 2.0, 13);
        let d = model.dim();
        let k = 4;
        // Reference: job 7 sampled alone.
        let model1 = MockArm::new(1, 2, 5, 4, 1, 2.0, 13);
        let mut ps1 = PredictiveSampler::new(&model1, Box::new(forecast::FpiReuse));
        ps1.reset_slot(0, JobNoise::new(1, 7, d, k));
        while !ps1.slot_done(0) {
            ps1.step().unwrap();
        }
        let ref7 = ps1.take_result(0).unwrap().x;

        let mut ps = PredictiveSampler::new(&model, Box::new(forecast::FpiReuse));
        ps.reset_slot(0, JobNoise::new(1, 0, d, k));
        ps.reset_slot(1, JobNoise::new(1, 7, d, k));
        // step until slot 1 finishes; then refill slot 1 with job 9.
        while !ps.slot_done(1) {
            ps.step().unwrap();
        }
        let got7 = ps.take_result(1).unwrap().x;
        assert_eq!(got7, ref7, "slot placement must not change the sample");
        ps.reset_slot(1, JobNoise::new(1, 9, d, k));
        while !ps.slot_done(0) || !ps.slot_done(1) {
            ps.step().unwrap();
        }
        assert!(ps.take_result(0).is_some());
        assert!(ps.take_result(1).is_some());
    }
}
