//! Figure-building from sampling traces: forecast-mistake maps (Figs. 3-5)
//! and convergence-iteration maps (Fig. 6).

use super::JobResult;
use crate::substrate::image::Image;

/// Per-pixel fraction of mispredicted channels, `[P]` in [0, 1]
/// (paper Figs. 3-4: 1/3, 2/3, 3/3 red for color images).
pub fn mistake_fractions(job: &JobResult, channels: usize) -> Vec<f32> {
    let pixels = job.mistakes.len() / channels;
    (0..pixels)
        .map(|p| {
            let wrong: u32 = (0..channels).map(|c| job.mistakes[p * channels + c] as u32).sum();
            wrong as f32 / channels as f32
        })
        .collect()
}

/// Per-pixel convergence iteration averaged over channels, `[P]`
/// (paper Fig. 6 input, before batch averaging).
pub fn convergence_map(job: &JobResult, channels: usize) -> Vec<f32> {
    let pixels = job.converge_iter.len() / channels;
    (0..pixels)
        .map(|p| {
            let s: u32 = (0..channels).map(|c| job.converge_iter[p * channels + c]).sum();
            s as f32 / channels as f32
        })
        .collect()
}

/// Average convergence maps over a batch of jobs (Fig. 6 averages over 32
/// samples and all channels).
pub fn mean_convergence_map(jobs: &[JobResult], channels: usize) -> Vec<f32> {
    assert!(!jobs.is_empty());
    let m0 = convergence_map(&jobs[0], channels);
    let mut acc = vec![0f32; m0.len()];
    for job in jobs {
        for (a, v) in acc.iter_mut().zip(convergence_map(job, channels)) {
            *a += v;
        }
    }
    for a in acc.iter_mut() {
        *a /= jobs.len() as f32;
    }
    acc
}

/// Render a grayscale sample (1-channel models, values in [0, K)).
pub fn render_gray(job: &JobResult, w: usize, h: usize, k: usize) -> Image {
    let vals: Vec<f32> = job.x.iter().map(|&v| v as f32 / (k - 1).max(1) as f32).collect();
    Image::from_gray(w, h, &vals)
}

/// Render an RGB sample from the channel-innermost flat layout.
pub fn render_rgb(job: &JobResult, w: usize, h: usize, channels: usize, k: usize) -> Image {
    assert!(channels >= 3);
    let mut im = Image::new(w, h);
    for y in 0..h {
        for x in 0..w {
            let p = y * w + x;
            let px = [
                (job.x[p * channels] as f32 / (k - 1) as f32 * 255.0) as u8,
                (job.x[p * channels + 1] as f32 / (k - 1) as f32 * 255.0) as u8,
                (job.x[p * channels + 2] as f32 / (k - 1) as f32 * 255.0) as u8,
            ];
            im.set(x, y, px);
        }
    }
    im
}

/// Sample + red mistake overlay (the paper's figure panels).
pub fn render_with_mistakes(job: &JobResult, w: usize, h: usize, channels: usize, k: usize) -> Image {
    let mut im = if channels >= 3 {
        render_rgb(job, w, h, channels, k)
    } else {
        render_gray(job, w, h, k)
    };
    im.overlay_mistakes(&mistake_fractions(job, channels));
    im
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(mistakes: Vec<u8>, converge: Vec<u32>, x: Vec<i32>) -> JobResult {
        JobResult { x, iterations: 5, mistakes, converge_iter: converge }
    }

    #[test]
    fn fractions_per_pixel() {
        // 2 pixels x 3 channels
        let j = job(vec![1, 1, 1, 0, 1, 0], vec![1; 6], vec![0; 6]);
        assert_eq!(mistake_fractions(&j, 3), vec![1.0, 1.0 / 3.0]);
    }

    #[test]
    fn convergence_average() {
        let j1 = job(vec![0; 4], vec![1, 1, 3, 3], vec![0; 4]);
        let j2 = job(vec![0; 4], vec![1, 1, 5, 5], vec![0; 4]);
        let m = mean_convergence_map(&[j1, j2], 2);
        assert_eq!(m, vec![1.0, 4.0]);
    }

    #[test]
    fn gray_and_rgb_render() {
        let j = job(vec![0; 4], vec![1; 4], vec![0, 1, 1, 0]);
        let im = render_gray(&j, 2, 2, 2);
        assert_eq!(im.get(1, 0), [255, 255, 255]);

        let j3 = job(vec![0; 12], vec![1; 12], vec![255, 0, 0, 0, 255, 0, 0, 0, 255, 255, 255, 255]);
        let im = render_rgb(&j3, 2, 2, 3, 256);
        assert_eq!(im.get(0, 0), [255, 0, 0]);
        assert_eq!(im.get(1, 0), [0, 255, 0]);
        assert_eq!(im.get(0, 1), [0, 0, 255]);
        assert_eq!(im.get(1, 1), [255, 255, 255]);
    }

    #[test]
    fn mistake_overlay_reddens() {
        let j = job(vec![1, 0, 0, 0], vec![1; 4], vec![1, 1, 1, 1]);
        let im = render_with_mistakes(&j, 2, 2, 1, 2);
        assert_eq!(im.get(0, 0), [255, 0, 0]); // mistaken pixel fully red
        assert_eq!(im.get(1, 0), [255, 255, 255]);
    }
}
