//! Deterministic pure-rust mock ARM for fast sampler/coordinator tests.
//!
//! Strictly autoregressive by construction: the logits of flat variable
//! `j` depend only on `x[j-1]` and `x[j-C]` (hash-table lookups), and the
//! forecast head at pixel `p` depends only on the last variable of pixel
//! `p-1`. A `strength` knob interpolates between near-uniform conditionals
//! (fast FPI convergence) and strongly-coupled ones (slow convergence), so
//! property tests cover both regimes without touching PJRT.
//!
//! The mock exploits [`PassPlan`]s fully — inactive rows are skipped,
//! each live row starts at its frontier, forecast heads are computed only
//! when a policy reads them (and then only for pixels the next query can
//! reach) — and large planned passes fan rows out across the shared
//! [`crate::substrate::threadpool::ThreadPool`]. Per-position logits are
//! pure functions of the input row, so planned and full passes are
//! bitwise identical on every position a plan promises.

use super::{PassPlan, StepModel};
use crate::runtime::step::StepOutput;
use crate::substrate::rng::splitmix64;
use anyhow::{ensure, Result};

#[derive(Clone, Debug)]
pub struct MockArm {
    pub batch: usize,
    pub channels: usize,
    pub pixels: usize,
    pub k: usize,
    pub t_fore: usize,
    /// Conditional coupling strength (0 = iid uniform-ish).
    pub strength: f32,
    /// Table seed — different seeds give different "models".
    pub seed: u64,
}

impl MockArm {
    pub fn new(batch: usize, channels: usize, pixels: usize, k: usize, t_fore: usize, strength: f32, seed: u64) -> MockArm {
        MockArm { batch, channels, pixels, k, t_fore, strength, seed }
    }

    #[inline]
    fn raw_logit(&self, key: u64, c: usize) -> f32 {
        let mut s = self.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (c as u64).wrapping_mul(0xABCD_EF12_3456_789B);
        let h = splitmix64(&mut s);
        // map to [-1, 1]
        ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
    }

    /// Normalized logp row for variable `j` given the input row `x`.
    fn logp_row(&self, x: &[i32], j: usize, out: &mut [f32]) {
        let prev1 = if j > 0 { x[j - 1] } else { -1 };
        let prevc = if j >= self.channels { x[j - self.channels] } else { -1 };
        let key = (j as u64) << 32 ^ ((prev1 as u64) & 0xFFFF) << 16 ^ ((prevc as u64) & 0xFFFF);
        let mut m = f32::NEG_INFINITY;
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.strength * self.raw_logit(key, c);
            m = m.max(*o);
        }
        let z: f32 = out.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        for o in out.iter_mut() {
            *o -= z;
        }
    }

    /// Forecast-head row for (pixel p, module t): depends only on the last
    /// variable of pixel p-1 (i.e. pixels < p), imitating the real model's
    /// validity contract. Roughly matches the ARM conditional when the
    /// relevant context coincides.
    fn fore_row(&self, x: &[i32], p: usize, t: usize, out: &mut [f32]) {
        let j = p * self.channels + t;
        let ctxv = if p > 0 { x[p * self.channels - 1] } else { -1 };
        let key = (j as u64) << 32 ^ ((ctxv as u64) & 0xFFFF) << 16 ^ ((ctxv as u64) & 0xFFFF);
        let mut m = f32::NEG_INFINITY;
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.strength * self.raw_logit(key, c);
            m = m.max(*o);
        }
        let z: f32 = out.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        for o in out.iter_mut() {
            *o -= z;
        }
    }

    /// Allocating convenience used by tests.
    pub fn run_into_owned(&self, x: &[i32]) -> StepOutput {
        let mut o = StepOutput::default();
        self.run_into(x, &mut o).expect("mock run");
        o
    }

    /// Fill one row's planned spans: logp for `[lo, hi)` and, when the
    /// heads are needed, fore rows for pixels `[fore_lo, P)`.
    fn fill_row(&self, row: &[i32], lo: usize, hi: usize, fore_lo: usize, logp: &mut [f32], fore: &mut [f32]) {
        let k = self.k;
        for (i, j) in (lo..hi).enumerate() {
            self.logp_row(row, j, &mut logp[i * k..(i + 1) * k]);
        }
        for (pi, p) in (fore_lo..self.pixels).enumerate() {
            for t in 0..self.t_fore {
                let o = (pi * self.t_fore + t) * k;
                self.fore_row(row, p, t, &mut fore[o..o + k]);
            }
        }
    }
}

impl StepModel for MockArm {
    fn batch(&self) -> usize {
        self.batch
    }
    fn dim(&self) -> usize {
        self.channels * self.pixels
    }
    fn categories(&self) -> usize {
        self.k
    }
    fn pixels(&self) -> usize {
        self.pixels
    }
    fn t_fore(&self) -> usize {
        self.t_fore
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        let d = self.dim();
        ensure!(x.len() == self.batch * d, "mock input len");
        out.logp.resize(self.batch * d * self.k, 0.0);
        out.fore.resize(self.batch * self.pixels * self.t_fore * self.k, 0.0);
        for b in 0..self.batch {
            let row = &x[b * d..(b + 1) * d];
            for j in 0..d {
                let o = (b * d + j) * self.k;
                self.logp_row(row, j, &mut out.logp[o..o + self.k]);
            }
            for p in 0..self.pixels {
                for t in 0..self.t_fore {
                    let o = ((b * self.pixels + p) * self.t_fore + t) * self.k;
                    self.fore_row(row, p, t, &mut out.fore[o..o + self.k]);
                }
            }
        }
        Ok(())
    }

    fn exploits_plan(&self) -> bool {
        true
    }

    fn run_plan(&self, x: &[i32], out: &mut StepOutput, plan: &PassPlan) -> Result<usize> {
        let d = self.dim();
        let k = self.k;
        ensure!(x.len() == self.batch * d, "mock input len");
        ensure!(plan.slots.len() == self.batch, "plan has {} spans for batch {}", plan.slots.len(), self.batch);
        out.logp.resize(self.batch * d * k, 0.0);
        if plan.need_fore {
            out.fore.resize(self.batch * self.pixels * self.t_fore * k, 0.0);
        } else {
            // Heads skipped this pass: leave the buffer empty so callers
            // see "absent" rather than a stale block.
            out.fore.clear();
        }
        // (slot, logp span, first fore pixel). The learned policy's next
        // query pixel q satisfies q*C <= frontier, and the frontier only
        // advances, so heads below lo/C can never be read again.
        let rows: Vec<(usize, usize, usize, usize)> = plan
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active)
            .map(|(b, s)| {
                let hi = s.hi.min(d);
                let lo = s.lo.min(hi);
                let fore_lo = if plan.need_fore { (lo / self.channels).min(self.pixels) } else { self.pixels };
                (b, lo, hi, fore_lo)
            })
            .collect();
        // Fan rows out across the shared pool when the planned work —
        // logp positions plus any head rows — is big enough to amortize
        // the dispatch; tiny passes stay serial.
        if rows.len() >= 2 && plan.rows(self.pixels, self.t_fore, self.channels) * k >= 4096 {
            let items: Vec<(usize, usize, usize, usize, Vec<i32>)> =
                rows.iter().map(|&(b, lo, hi, fore_lo)| (b, lo, hi, fore_lo, x[b * d..(b + 1) * d].to_vec())).collect();
            let arm = self.clone();
            let segs = crate::substrate::threadpool::shared().map(items, move |(b, lo, hi, fore_lo, row)| {
                let mut logp = vec![0f32; (hi - lo) * arm.k];
                let mut fore = vec![0f32; (arm.pixels - fore_lo) * arm.t_fore * arm.k];
                arm.fill_row(&row, lo, hi, fore_lo, &mut logp, &mut fore);
                (b, lo, fore_lo, logp, fore)
            });
            for (b, lo, fore_lo, logp, fore) in segs {
                let o = (b * d + lo) * k;
                out.logp[o..o + logp.len()].copy_from_slice(&logp);
                if !fore.is_empty() {
                    let o = (b * self.pixels + fore_lo) * self.t_fore * k;
                    out.fore[o..o + fore.len()].copy_from_slice(&fore);
                }
            }
        } else {
            for &(b, lo, hi, fore_lo) in &rows {
                let row = &x[b * d..(b + 1) * d];
                let (lp_lo, lp_hi) = ((b * d + lo) * k, (b * d + hi) * k);
                let (fo_lo, fo_hi) = ((b * self.pixels + fore_lo) * self.t_fore * k, (b + 1) * self.pixels * self.t_fore * k);
                let fore = if plan.need_fore { &mut out.fore[fo_lo..fo_hi] } else { &mut [][..] };
                self.fill_row(row, lo, hi, fore_lo, &mut out.logp[lp_lo..lp_hi], fore);
            }
        }
        Ok(plan.rows(self.pixels, self.t_fore, self.channels))
    }
}

/// The mock ARM can also pose as one `(batch, span, fore)` *device shape*
/// for a [`crate::runtime::step::VariantCatalog`], so catalog-backed
/// engines, benches, and A/B tests run offline. Per-position logits are
/// pure functions of the input row, so a trailing-window pass is bitwise
/// identical to the same window of a full pass — exactly the property the
/// compiled span exports get from autoregression.
impl crate::runtime::step::SpanBackend for MockArm {
    fn run_span(&self, batch: usize, span: usize, has_fore: bool, x: &[i32], out: &mut StepOutput) -> Result<()> {
        let d = self.dim();
        let k = self.k;
        ensure!(span >= 1 && span <= d, "mock span {span} out of range (d={d})");
        ensure!(x.len() == batch * d, "mock span input len");
        out.logp.resize(batch * span * k, 0.0);
        let base = d - span;
        if has_fore {
            out.fore.resize(batch * self.pixels * self.t_fore * k, 0.0);
        } else {
            out.fore.clear();
        }
        for b in 0..batch {
            let row = &x[b * d..(b + 1) * d];
            for (i, j) in (base..d).enumerate() {
                let o = (b * span + i) * k;
                self.logp_row(row, j, &mut out.logp[o..o + k]);
            }
            if has_fore {
                for p in 0..self.pixels {
                    for t in 0..self.t_fore {
                        let o = ((b * self.pixels + p) * self.t_fore + t) * k;
                        self.fore_row(row, p, t, &mut out.fore[o..o + k]);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strictly_autoregressive() {
        let m = MockArm::new(1, 3, 4, 5, 2, 2.0, 0);
        let d = m.dim();
        let x0 = vec![0i32; d];
        for j in 0..d {
            let mut x1 = x0.clone();
            x1[j] = 3;
            let o0 = m.run_into_owned(&x0);
            let o1 = m.run_into_owned(&x1);
            let k = m.k;
            assert_eq!(&o0.logp[..(j + 1) * k], &o1.logp[..(j + 1) * k], "leak at {j}");
        }
    }

    #[test]
    fn fore_depends_only_on_past_pixels() {
        let m = MockArm::new(1, 3, 4, 5, 2, 2.0, 0);
        let d = m.dim();
        let x0 = vec![1i32; d];
        let mut x1 = x0.clone();
        // perturb pixel 2 (vars 6..9): fore rows for pixels <= 2 unchanged
        x1[6] = 4;
        let o0 = m.run_into_owned(&x0);
        let o1 = m.run_into_owned(&x1);
        let row = m.t_fore * m.k;
        assert_eq!(&o0.fore[..3 * row], &o1.fore[..3 * row]);
    }

    #[test]
    fn run_plan_matches_run_into_on_planned_positions() {
        use crate::sampler::SlotSpan;
        let m = MockArm::new(3, 2, 5, 4, 2, 2.0, 9);
        let d = m.dim();
        let k = m.k;
        let x: Vec<i32> = (0..3 * d).map(|i| (i % 4) as i32).collect();
        let full = m.run_into_owned(&x);
        let plan = PassPlan {
            slots: vec![
                SlotSpan { active: true, lo: 3, hi: d },
                SlotSpan { active: false, lo: 0, hi: 0 },
                SlotSpan { active: true, lo: 0, hi: 1 },
            ],
            need_fore: true,
            need_full_scan: true,
        };
        let mut out = StepOutput::default();
        m.run_plan(&x, &mut out, &plan).unwrap();
        assert_eq!(out.logp.len(), full.logp.len());
        // Slot 0: positions >= 3 bitwise equal; slot 2: position 0 only.
        assert_eq!(&out.logp[3 * k..d * k], &full.logp[3 * k..d * k]);
        assert_eq!(&out.logp[2 * d * k..(2 * d + 1) * k], &full.logp[2 * d * k..(2 * d + 1) * k]);
        // Fore heads: slot 0 pixels >= lo/C = 1, slot 2 all pixels.
        let row = m.t_fore * k;
        let pr = m.pixels * row;
        assert_eq!(&out.fore[row..pr], &full.fore[row..pr], "slot 0 heads from pixel 1");
        assert_eq!(&out.fore[2 * pr..3 * pr], &full.fore[2 * pr..3 * pr], "slot 2 heads");
    }

    #[test]
    fn run_plan_parallel_path_is_bitwise_exact() {
        // Big enough to cross the pool threshold (positions * k >= 4096).
        let m = MockArm::new(4, 3, 24, 16, 2, 2.0, 5);
        let d = m.dim();
        let k = m.k;
        let x: Vec<i32> = (0..4 * d).map(|i| (i % 16) as i32).collect();
        let full = m.run_into_owned(&x);
        let mut out = StepOutput::default();
        m.run_plan(&x, &mut out, &PassPlan::full(4, d)).unwrap();
        assert!(4 * d * k >= 4096, "fixture must engage the parallel path");
        assert_eq!(out.logp, full.logp, "parallel planned pass diverged from serial full pass");
        assert_eq!(out.fore, full.fore);
    }

    #[test]
    fn run_plan_skips_fore_when_unread() {
        let m = MockArm::new(2, 2, 5, 4, 2, 2.0, 9);
        let d = m.dim();
        let x = vec![0i32; 2 * d];
        let mut plan = PassPlan::full(2, d);
        plan.need_fore = false;
        let mut out = StepOutput::default();
        out.fore = vec![1.0; 7]; // stale garbage from a previous pass
        m.run_plan(&x, &mut out, &plan).unwrap();
        assert!(out.fore.is_empty(), "skipped heads must read as absent");
        assert_eq!(out.logp, m.run_into_owned(&x).logp);
    }

    #[test]
    fn span_backend_matches_full_pass_window() {
        use crate::runtime::step::SpanBackend;
        let m = MockArm::new(2, 2, 5, 4, 2, 2.0, 9);
        let d = m.dim();
        let k = m.k;
        let x: Vec<i32> = (0..2 * d as i32).map(|i| i % 4).collect();
        let full = m.run_into_owned(&x);
        for span in [1, 3, d] {
            let base = d - span;
            let mut out = StepOutput::default();
            m.run_span(2, span, true, &x, &mut out).unwrap();
            for b in 0..2 {
                assert_eq!(
                    &out.logp[b * span * k..(b + 1) * span * k],
                    &full.logp[(b * d + base) * k..(b + 1) * d * k],
                    "span {span} row {b}"
                );
            }
            assert_eq!(out.fore, full.fore, "span {span} heads");
            let mut lp = StepOutput::default();
            m.run_span(2, span, false, &x, &mut lp).unwrap();
            assert_eq!(lp.logp, out.logp);
            assert!(lp.fore.is_empty());
        }
    }

    #[test]
    fn logp_normalized() {
        let m = MockArm::new(2, 2, 3, 4, 1, 1.5, 7);
        let out = m.run_into_owned(&vec![1i32; 2 * m.dim()]);
        for j in 0..2 * m.dim() {
            let s: f32 = out.logp[j * 4..(j + 1) * 4].iter().map(|l| l.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }
}
