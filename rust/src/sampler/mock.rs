//! Deterministic pure-rust mock ARM for fast sampler/coordinator tests.
//!
//! Strictly autoregressive by construction: the logits of flat variable
//! `j` depend only on `x[j-1]` and `x[j-C]` (hash-table lookups), and the
//! forecast head at pixel `p` depends only on the last variable of pixel
//! `p-1`. A `strength` knob interpolates between near-uniform conditionals
//! (fast FPI convergence) and strongly-coupled ones (slow convergence), so
//! property tests cover both regimes without touching PJRT.

use super::StepModel;
use crate::runtime::step::StepOutput;
use crate::substrate::rng::splitmix64;
use anyhow::{ensure, Result};

#[derive(Clone, Debug)]
pub struct MockArm {
    pub batch: usize,
    pub channels: usize,
    pub pixels: usize,
    pub k: usize,
    pub t_fore: usize,
    /// Conditional coupling strength (0 = iid uniform-ish).
    pub strength: f32,
    /// Table seed — different seeds give different "models".
    pub seed: u64,
}

impl MockArm {
    pub fn new(batch: usize, channels: usize, pixels: usize, k: usize, t_fore: usize, strength: f32, seed: u64) -> MockArm {
        MockArm { batch, channels, pixels, k, t_fore, strength, seed }
    }

    #[inline]
    fn raw_logit(&self, key: u64, c: usize) -> f32 {
        let mut s = self.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (c as u64).wrapping_mul(0xABCD_EF12_3456_789B);
        let h = splitmix64(&mut s);
        // map to [-1, 1]
        ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
    }

    /// Normalized logp row for variable `j` given the input row `x`.
    fn logp_row(&self, x: &[i32], j: usize, out: &mut [f32]) {
        let prev1 = if j > 0 { x[j - 1] } else { -1 };
        let prevc = if j >= self.channels { x[j - self.channels] } else { -1 };
        let key = (j as u64) << 32 ^ ((prev1 as u64) & 0xFFFF) << 16 ^ ((prevc as u64) & 0xFFFF);
        let mut m = f32::NEG_INFINITY;
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.strength * self.raw_logit(key, c);
            m = m.max(*o);
        }
        let z: f32 = out.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        for o in out.iter_mut() {
            *o -= z;
        }
    }

    /// Forecast-head row for (pixel p, module t): depends only on the last
    /// variable of pixel p-1 (i.e. pixels < p), imitating the real model's
    /// validity contract. Roughly matches the ARM conditional when the
    /// relevant context coincides.
    fn fore_row(&self, x: &[i32], p: usize, t: usize, out: &mut [f32]) {
        let j = p * self.channels + t;
        let ctxv = if p > 0 { x[p * self.channels - 1] } else { -1 };
        let key = (j as u64) << 32 ^ ((ctxv as u64) & 0xFFFF) << 16 ^ ((ctxv as u64) & 0xFFFF);
        let mut m = f32::NEG_INFINITY;
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.strength * self.raw_logit(key, c);
            m = m.max(*o);
        }
        let z: f32 = out.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        for o in out.iter_mut() {
            *o -= z;
        }
    }

    /// Allocating convenience used by tests.
    pub fn run_into_owned(&self, x: &[i32]) -> StepOutput {
        let mut o = StepOutput::default();
        self.run_into(x, &mut o).expect("mock run");
        o
    }
}

impl StepModel for MockArm {
    fn batch(&self) -> usize {
        self.batch
    }
    fn dim(&self) -> usize {
        self.channels * self.pixels
    }
    fn categories(&self) -> usize {
        self.k
    }
    fn pixels(&self) -> usize {
        self.pixels
    }
    fn t_fore(&self) -> usize {
        self.t_fore
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        let d = self.dim();
        ensure!(x.len() == self.batch * d, "mock input len");
        out.logp.resize(self.batch * d * self.k, 0.0);
        out.fore.resize(self.batch * self.pixels * self.t_fore * self.k, 0.0);
        for b in 0..self.batch {
            let row = &x[b * d..(b + 1) * d];
            for j in 0..d {
                let o = (b * d + j) * self.k;
                self.logp_row(row, j, &mut out.logp[o..o + self.k]);
            }
            for p in 0..self.pixels {
                for t in 0..self.t_fore {
                    let o = ((b * self.pixels + p) * self.t_fore + t) * self.k;
                    self.fore_row(row, p, t, &mut out.fore[o..o + self.k]);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strictly_autoregressive() {
        let m = MockArm::new(1, 3, 4, 5, 2, 2.0, 0);
        let d = m.dim();
        let x0 = vec![0i32; d];
        for j in 0..d {
            let mut x1 = x0.clone();
            x1[j] = 3;
            let o0 = m.run_into_owned(&x0);
            let o1 = m.run_into_owned(&x1);
            let k = m.k;
            assert_eq!(&o0.logp[..(j + 1) * k], &o1.logp[..(j + 1) * k], "leak at {j}");
        }
    }

    #[test]
    fn fore_depends_only_on_past_pixels() {
        let m = MockArm::new(1, 3, 4, 5, 2, 2.0, 0);
        let d = m.dim();
        let x0 = vec![1i32; d];
        let mut x1 = x0.clone();
        // perturb pixel 2 (vars 6..9): fore rows for pixels <= 2 unchanged
        x1[6] = 4;
        let o0 = m.run_into_owned(&x0);
        let o1 = m.run_into_owned(&x1);
        let row = m.t_fore * m.k;
        assert_eq!(&o0.fore[..3 * row], &o1.fore[..3 * row]);
    }

    #[test]
    fn logp_normalized() {
        let m = MockArm::new(2, 2, 3, 4, 1, 1.5, 7);
        let out = m.run_into_owned(&vec![1i32; 2 * m.dim()]);
        for j in 0..2 * m.dim() {
            let s: f32 = out.logp[j * 4..(j + 1) * 4].iter().map(|l| l.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }
}
