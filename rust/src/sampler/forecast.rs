//! Forecaster policies (paper §2.2-2.4 + Table 1 baselines + Table 3
//! ablation). A policy fills the suffix `x[i..d]` of the next ARM input
//! with forecasts, given everything valid so far.

use super::noise::JobNoise;
use crate::substrate::gumbel::{argmax, gumbel_argmax};

/// Everything a policy may condition on when forecasting for one job.
pub struct ForecastCtx<'a> {
    /// Frontier: variables `< i` of `x` are valid samples.
    pub i: usize,
    pub dim: usize,
    pub channels: usize,
    pub k: usize,
    pub t_fore: usize,
    pub pixels: usize,
    /// Reparametrized ARM outputs of the *previous* pass, full `[d]`
    /// (zeros before the first pass).
    pub out_prev: &'a [i32],
    /// Greedy (no-noise) ARM outputs of the previous pass `[d]`.
    pub greedy_prev: &'a [i32],
    /// Forecast-head log-probs of the previous pass `[P, T, K]`
    /// (empty before the first pass).
    pub fore_prev: &'a [f32],
    /// The job's reparametrization noise.
    pub noise: &'a JobNoise,
    /// True on the first pass (no previous outputs exist).
    pub first: bool,
}

/// A forecasting function F_i (paper Eq. 3/6).
pub trait Forecaster: Send + Sync {
    fn name(&self) -> &'static str;
    /// Fill `x[ctx.i..]` with forecasts. `x` is the full `[d]` input row;
    /// the valid prefix must not be touched.
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]);
    /// False for the no-reparametrization ablation (Table 3): noise is
    /// redrawn every pass, so forecast agreement is not exact-valued.
    fn reparametrized(&self) -> bool {
        true
    }
    /// Whether `forecast` reads `ctx.fore_prev`. Only the learned policy
    /// does; every other policy lets the engine skip computing (and, for
    /// compiled models, transferring) the forecast heads entirely.
    fn reads_fore(&self) -> bool {
        false
    }
    /// Whether `forecast` reads `ctx.out_prev` / `ctx.greedy_prev` beyond
    /// the frontier. Policies that don't (zeros, predict-last) let the
    /// sampler stop scanning outputs at the first forecast disagreement
    /// instead of materializing the whole reparametrized tail.
    fn reads_prev_tail(&self) -> bool {
        true
    }
}

/// Baseline: forecast zeros (paper §4.1, binary MNIST baseline).
pub struct Zeros;

impl Forecaster for Zeros {
    fn name(&self) -> &'static str {
        "zeros"
    }
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]) {
        for v in x[ctx.i..].iter_mut() {
            *v = 0;
        }
    }
    fn reads_prev_tail(&self) -> bool {
        false
    }
}

/// Baseline: repeat the last observed value (paper §4.1 "predict last").
pub struct PredictLast;

impl Forecaster for PredictLast {
    fn name(&self) -> &'static str {
        "predict_last"
    }
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]) {
        let last = if ctx.i > 0 { x[ctx.i - 1] } else { 0 };
        for v in x[ctx.i..].iter_mut() {
            *v = last;
        }
    }
    fn reads_prev_tail(&self) -> bool {
        false
    }
}

/// ARM fixed-point iteration (paper §2.3): reuse the previous pass's
/// reparametrized outputs as forecasts. Algorithm 1 with this policy is
/// equivalent to Algorithm 2.
pub struct FpiReuse;

impl Forecaster for FpiReuse {
    fn name(&self) -> &'static str {
        "fpi"
    }
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]) {
        if ctx.first {
            for v in x[ctx.i..].iter_mut() {
                *v = 0;
            }
        } else {
            x[ctx.i..].copy_from_slice(&ctx.out_prev[ctx.i..]);
        }
    }
}

/// Learned forecasting modules (paper §2.4) on top of FPI: the first
/// `t_use` future variables come from the forecast heads (trained to match
/// the ARM's conditionals given only valid information), the rest from the
/// previous ARM outputs ("forecasts for all remaining future timesteps are
/// taken from the ARM output").
pub struct Learned {
    /// How many of the trained T modules to use (paper reports T=1/5/20).
    pub t_use: usize,
}

impl Forecaster for Learned {
    fn name(&self) -> &'static str {
        "learned"
    }
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]) {
        if ctx.first {
            for v in x[ctx.i..].iter_mut() {
                *v = 0;
            }
            return;
        }
        // Query pixel q: the last pixel whose representation h(q) is
        // guaranteed valid. The previous pass's input was valid up to
        // i-1, and h(q) depends on pixels < q, i.e. variables < q*C; so
        // the largest safe q has q*C <= i-1.
        let c = ctx.channels;
        let q = (ctx.i - 1) / c; // ctx.i >= 1 when !first
        let t_use = self.t_use.min(ctx.t_fore);
        for j in ctx.i..ctx.dim {
            let t = j - q * c;
            x[j] = if t < t_use {
                let row = &ctx.fore_prev[(q * ctx.t_fore + t) * ctx.k..(q * ctx.t_fore + t + 1) * ctx.k];
                gumbel_argmax(row, ctx.noise.row(j)) as i32
            } else {
                ctx.out_prev[j]
            };
        }
    }
    fn reads_fore(&self) -> bool {
        true
    }
}

/// Table-3 ablation: fixed-point iteration *without* reparametrization.
/// Forecasts are the greedy argmax of the previous pass's distributions
/// (no ε term), and the engine redraws sampling noise every pass.
pub struct NoReparam;

impl Forecaster for NoReparam {
    fn name(&self) -> &'static str {
        "fpi_noreparam"
    }
    fn forecast(&self, ctx: &ForecastCtx<'_>, x: &mut [i32]) {
        if ctx.first {
            for v in x[ctx.i..].iter_mut() {
                *v = 0;
            }
        } else {
            x[ctx.i..].copy_from_slice(&ctx.greedy_prev[ctx.i..]);
        }
    }
    fn reparametrized(&self) -> bool {
        false
    }
}

/// Parse a policy by CLI name.
pub fn by_name(name: &str, t_use: usize) -> Option<Box<dyn Forecaster>> {
    match name {
        "zeros" => Some(Box::new(Zeros)),
        "last" | "predict_last" => Some(Box::new(PredictLast)),
        "fpi" => Some(Box::new(FpiReuse)),
        "forecast" | "learned" => Some(Box::new(Learned { t_use: t_use.max(1) })),
        "noreparam" | "fpi_noreparam" => Some(Box::new(NoReparam)),
        _ => None,
    }
}

/// Greedy argmax over a logp row — helper shared with the engine.
pub fn greedy_of(logp_row: &[f32]) -> i32 {
    argmax(logp_row) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(i: usize, out_prev: &'a [i32], greedy: &'a [i32], fore: &'a [f32], noise: &'a JobNoise, first: bool) -> ForecastCtx<'a> {
        ForecastCtx {
            i,
            dim: 12,
            channels: 3,
            k: 4,
            t_fore: 2,
            pixels: 4,
            out_prev,
            greedy_prev: greedy,
            fore_prev: fore,
            noise,
            first,
        }
    }

    #[test]
    fn zeros_and_last() {
        let noise = JobNoise::new(0, 0, 12, 4);
        let out = vec![1i32; 12];
        let mut x = vec![3i32; 12];
        Zeros.forecast(&ctx(4, &out, &out, &[], &noise, false), &mut x);
        assert_eq!(&x[..4], &[3, 3, 3, 3]);
        assert!(x[4..].iter().all(|&v| v == 0));

        let mut x = vec![7i32; 12];
        PredictLast.forecast(&ctx(4, &out, &out, &[], &noise, false), &mut x);
        assert!(x[4..].iter().all(|&v| v == 7));
        let mut x = vec![7i32; 12];
        PredictLast.forecast(&ctx(0, &out, &out, &[], &noise, true), &mut x);
        assert!(x.iter().all(|&v| v == 0));
    }

    #[test]
    fn fpi_reuses_prev_outputs() {
        let noise = JobNoise::new(0, 0, 12, 4);
        let out: Vec<i32> = (0..12).collect();
        let mut x = vec![9i32; 12];
        FpiReuse.forecast(&ctx(5, &out, &out, &[], &noise, false), &mut x);
        assert_eq!(&x[..5], &[9; 5]);
        assert_eq!(&x[5..], &out[5..]);
    }

    #[test]
    fn learned_uses_heads_then_arm() {
        let noise = JobNoise::new(1, 0, 12, 4);
        let out: Vec<i32> = (0..12).map(|j| (j % 4) as i32).collect();
        // fore logp [P=4, T=2, K=4]: strongly peak category 2 everywhere
        let mut fore = vec![-10.0f32; 4 * 2 * 4];
        for p in 0..4 {
            for t in 0..2 {
                fore[(p * 2 + t) * 4 + 2] = 10.0;
            }
        }
        let f = Learned { t_use: 2 };
        let mut x = vec![0i32; 12];
        // frontier i=4 -> q=(4-1)/3=1; t offsets j-3: j=4 -> t=1 (<2, head), j=5 -> t=2 (ARM)
        f.forecast(&ctx(4, &out, &out, &fore, &noise, false), &mut x);
        assert_eq!(x[4], 2, "head forecast should win (strong peak)");
        assert_eq!(x[5], out[5]);
        assert_eq!(&x[6..], &out[6..]);
    }

    #[test]
    fn noreparam_uses_greedy() {
        let noise = JobNoise::new(0, 0, 12, 4);
        let out = vec![1i32; 12];
        let greedy = vec![2i32; 12];
        let mut x = vec![0i32; 12];
        NoReparam.forecast(&ctx(3, &out, &greedy, &[], &noise, false), &mut x);
        assert!(x[3..].iter().all(|&v| v == 2));
        assert!(!NoReparam.reparametrized());
    }

    #[test]
    fn capability_flags_match_what_policies_read() {
        // The pass-plan machinery derives skip decisions from these flags,
        // so they must agree with each forecast() implementation.
        assert!(!Zeros.reads_fore() && !Zeros.reads_prev_tail());
        assert!(!PredictLast.reads_fore() && !PredictLast.reads_prev_tail());
        assert!(!FpiReuse.reads_fore() && FpiReuse.reads_prev_tail());
        let learned = Learned { t_use: 2 };
        assert!(learned.reads_fore() && learned.reads_prev_tail());
        assert!(!NoReparam.reads_fore() && NoReparam.reads_prev_tail());
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["zeros", "last", "fpi", "learned", "noreparam"] {
            assert!(by_name(n, 1).is_some(), "{n}");
        }
        assert!(by_name("bogus", 1).is_none());
    }
}
