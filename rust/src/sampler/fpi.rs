//! ARM fixed-point iteration — the paper's Algorithm 2, implemented
//! literally: iterate `x^{(n+1)} = g(x^{(n)}, ε)` until the iterate stops
//! changing. Included both as the paper presents it and as an equivalence
//! witness for Algorithm 1 + the FPI-reuse policy (they must produce the
//! same sample in the same number of passes — tested below).

use super::noise::JobNoise;
use super::{JobResult, StepModel};
use crate::runtime::step::StepOutput;
use crate::substrate::gumbel::gumbel_argmax;
use anyhow::Result;

/// Run Algorithm 2 for a single job (slot 0 of the model).
pub fn fixed_point_sample<M: StepModel>(model: &M, noise: &JobNoise) -> Result<JobResult> {
    let d = model.dim();
    let k = model.categories();
    let b = model.batch();
    let mut x = vec![0i32; b * d];
    let mut x_new = x.clone();
    let mut out = StepOutput::default();
    let mut mistakes = vec![0u8; d];
    let mut converge_iter = vec![0u32; d];
    let mut finalized = vec![false; d];
    let mut iters = 0usize;

    loop {
        model.run_into(&x, &mut out)?;
        iters += 1;
        for j in 0..d {
            let lp = &out.logp[j * k..(j + 1) * k];
            x_new[j] = gumbel_argmax(lp, noise.row(j)) as i32;
        }
        // Trace bookkeeping: the longest prefix on which the new iterate
        // agrees with the old one is now final.
        let mut prefix = 0;
        while prefix < d && x_new[prefix] == x[prefix] {
            prefix += 1;
        }
        for (j, fin) in finalized.iter_mut().enumerate().take(prefix.min(d)) {
            if !*fin {
                *fin = true;
                converge_iter[j] = iters as u32;
            }
        }
        if prefix < d && !finalized[prefix] {
            // the rejection point: a forecast mistake in Algorithm-1 terms
            mistakes[prefix] = 1;
            finalized[prefix] = true;
            converge_iter[prefix] = iters as u32;
        }
        if x_new[..d] == x[..d] {
            break;
        }
        x[..d].copy_from_slice(&x_new[..d]);
        if iters > d + 1 {
            anyhow::bail!("fixed-point iteration failed to converge in d+1 passes");
        }
    }
    Ok(JobResult { x: x[..d].to_vec(), iterations: iters, mistakes, converge_iter })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ancestral::ancestral_sample;
    use crate::sampler::forecast::FpiReuse;
    use crate::sampler::mock::MockArm;
    use crate::sampler::predictive::PredictiveSampler;
    use crate::substrate::proptest_lite::check;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn algorithm2_equals_ancestral() {
        check("fpi-exactness", 10, |g| {
            let model = MockArm::new(
                1,
                g.usize_in(1, 4),
                g.usize_in(2, 6),
                g.usize_in(2, 6),
                1,
                g.f64_in(0.0, 4.0) as f32,
                g.rng.next_u64(),
            );
            let noise = JobNoise::new(g.rng.next_u64(), 0, model.dim(), model.categories());
            let anc = ancestral_sample(&model, &noise).map_err(|e| e.to_string())?;
            let fpi = fixed_point_sample(&model, &noise).map_err(|e| e.to_string())?;
            prop_assert_eq!(&fpi.x, &anc.x, "Algorithm 2 diverged");
            prop_assert!(fpi.iterations <= model.dim() + 1, "too many iterations");
            Ok(())
        });
    }

    #[test]
    fn algorithm2_equals_algorithm1_with_fpi_policy() {
        // The paper's §2.3 equivalence claim, checked mechanically. The
        // literal Algorithm 2 needs one extra pass to *verify* the fixed
        // point; Algorithm 1 knows convergence from the frontier, so its
        // count may be one lower.
        check("alg1-alg2-equivalence", 10, |g| {
            let model = MockArm::new(
                1,
                g.usize_in(1, 3),
                g.usize_in(2, 6),
                g.usize_in(2, 5),
                1,
                g.f64_in(0.5, 4.0) as f32,
                g.rng.next_u64(),
            );
            let seed = g.rng.next_u64();
            let noise = JobNoise::new(seed, 0, model.dim(), model.categories());
            let alg2 = fixed_point_sample(&model, &noise).map_err(|e| e.to_string())?;

            let mut ps = PredictiveSampler::new(&model, Box::new(FpiReuse));
            ps.reset_slot(0, JobNoise::new(seed, 0, model.dim(), model.categories()));
            while !ps.slot_done(0) {
                ps.step().map_err(|e| e.to_string())?;
            }
            let alg1 = ps.take_result(0).unwrap();
            prop_assert_eq!(&alg1.x, &alg2.x, "samples differ");
            prop_assert!(
                alg2.iterations >= alg1.iterations && alg2.iterations <= alg1.iterations + 1,
                "pass counts inconsistent: alg1={} alg2={}",
                alg1.iterations,
                alg2.iterations
            );
            Ok(())
        });
    }
}
