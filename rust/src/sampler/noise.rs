//! Reparametrization-noise lifecycle (paper §2.2).
//!
//! Each sampling job owns an independent ε ~ G^{d×K} block, derived from
//! `(seed, job_id)` so the noise — and therefore the *sample*, thanks to
//! reparametrized determinism — is identical regardless of batch placement
//! or scheduling. The continuous-batching scheduler's equivalence tests
//! rely on this.

use crate::substrate::gumbel::fill_gumbel;
use crate::substrate::rng::Rng;

/// Per-job Gumbel noise block `[d, K]` plus the job's private RNG stream
/// (used further only by the no-reparametrization ablation, which redraws
/// noise each iteration).
#[derive(Clone, Debug)]
pub struct JobNoise {
    pub eps: Vec<f32>,
    pub dim: usize,
    pub k: usize,
    pub rng: Rng,
}

impl JobNoise {
    /// Deterministic noise for `(seed, job_id)`.
    pub fn new(seed: u64, job_id: u64, dim: usize, k: usize) -> JobNoise {
        let mut rng = Rng::for_stream(seed, job_id);
        let mut eps = vec![0f32; dim * k];
        fill_gumbel(&mut rng, &mut eps);
        JobNoise { eps, dim, k, rng }
    }

    /// ε row for flat variable `j`.
    #[inline]
    pub fn row(&self, j: usize) -> &[f32] {
        &self.eps[j * self.k..(j + 1) * self.k]
    }

    /// Redraw all noise in place from the job RNG (no-reparametrization
    /// ablation: a fresh draw per ARM pass).
    pub fn redraw(&mut self) {
        let mut rng = self.rng.clone();
        fill_gumbel(&mut rng, &mut self.eps);
        self.rng = rng;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_job() {
        let a = JobNoise::new(7, 3, 10, 4);
        let b = JobNoise::new(7, 3, 10, 4);
        assert_eq!(a.eps, b.eps);
    }

    #[test]
    fn jobs_independent() {
        let a = JobNoise::new(7, 0, 10, 4);
        let b = JobNoise::new(7, 1, 10, 4);
        assert_ne!(a.eps, b.eps);
    }

    #[test]
    fn rows_slice_correctly() {
        let n = JobNoise::new(0, 0, 5, 3);
        assert_eq!(n.row(2), &n.eps[6..9]);
        assert_eq!(n.row(4).len(), 3);
    }

    #[test]
    fn redraw_changes_noise_deterministically() {
        let mut a = JobNoise::new(1, 1, 8, 2);
        let before = a.eps.clone();
        a.redraw();
        assert_ne!(a.eps, before);
        let mut b = JobNoise::new(1, 1, 8, 2);
        b.redraw();
        assert_eq!(a.eps, b.eps);
    }
}
