//! The paper's contribution: predictive sampling for ARMs.
//!
//! * [`predictive`] — Algorithm 1, batched, generic over a forecaster
//!   policy and over [`StepModel`] (so invariants are property-tested
//!   against a pure-rust mock ARM as well as the compiled artifacts).
//! * [`fpi`] — Algorithm 2 (ARM fixed-point iteration), plus its
//!   equivalence to Algorithm 1 with the FPI-reuse policy.
//! * [`forecast`] — forecaster policies: zeros / predict-last / FPI /
//!   learned modules / no-reparametrization ablation.
//! * [`ancestral`] — the d-call baseline.
//! * [`noise`] — per-job reparametrization noise (ε lifecycle).
//! * [`trace`] — mistake maps and convergence maps (paper Figs. 3-6).
//! * [`mock`] — deterministic pure-rust ARM for fast tests.

pub mod ancestral;
pub mod forecast;
pub mod fpi;
pub mod mock;
pub mod noise;
pub mod predictive;
pub mod trace;

use crate::runtime::step::{StepExecutable, StepOutput};
use anyhow::Result;

/// Abstraction over the ARM's parallel-inference pass. Implemented by the
/// compiled PJRT executable and by [`mock::MockArm`] for tests.
pub trait StepModel {
    fn batch(&self) -> usize;
    fn dim(&self) -> usize;
    fn categories(&self) -> usize;
    fn pixels(&self) -> usize;
    fn t_fore(&self) -> usize;
    /// Data channels per pixel (flat layout is channel-innermost).
    fn channels(&self) -> usize {
        self.dim() / self.pixels()
    }
    /// One parallel pass: x i32[B,d] -> logp [B,d,K], fore [B,P,T,K].
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()>;
}

impl StepModel for StepExecutable {
    fn batch(&self) -> usize {
        self.batch
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn categories(&self) -> usize {
        self.categories
    }
    fn pixels(&self) -> usize {
        self.pixels
    }
    fn t_fore(&self) -> usize {
        self.t_fore
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        StepExecutable::run_into(self, x, out)
    }
}

/// Result of sampling one image/latent.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The sample, flat `[d]`.
    pub x: Vec<i32>,
    /// ARM passes until *this* job converged.
    pub iterations: usize,
    /// Per-variable: 1 if the forecast for that variable was wrong when it
    /// was finalized (the red pixels of Figs. 3-5).
    pub mistakes: Vec<u8>,
    /// Per-variable: the pass index (1-based) at which the variable's
    /// final value was determined (Fig. 6).
    pub converge_iter: Vec<u32>,
}

/// Result of sampling a batch.
#[derive(Clone, Debug)]
pub struct BatchResult {
    pub jobs: Vec<JobResult>,
    /// ARM passes for the whole batch — the slowest job determines this
    /// (paper §4.1's batched semantics).
    pub arm_calls: usize,
    pub wall_secs: f64,
}

impl BatchResult {
    /// ARM calls as a percentage of the baseline's d calls.
    pub fn calls_pct(&self, d: usize) -> f64 {
        100.0 * self.arm_calls as f64 / d as f64
    }
}
