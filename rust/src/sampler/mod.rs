//! The paper's contribution: predictive sampling for ARMs.
//!
//! * [`predictive`] — Algorithm 1, batched, generic over a forecaster
//!   policy and over [`StepModel`] (so invariants are property-tested
//!   against a pure-rust mock ARM as well as the compiled artifacts).
//! * [`fpi`] — Algorithm 2 (ARM fixed-point iteration), plus its
//!   equivalence to Algorithm 1 with the FPI-reuse policy.
//! * [`forecast`] — forecaster policies: zeros / predict-last / FPI /
//!   learned modules / no-reparametrization ablation.
//! * [`ancestral`] — the d-call baseline.
//! * [`noise`] — per-job reparametrization noise (ε lifecycle).
//! * [`trace`] — mistake maps and convergence maps (paper Figs. 3-6).
//! * [`mock`] — deterministic pure-rust ARM for fast tests.
//!
//! The sampling hot path is *frontier-aware*: each pass the sampler hands
//! its backend a [`PassPlan`] describing which batch rows are live, which
//! positions of each row will actually be read (everything below a slot's
//! frontier is overwritten by the valid prefix, everything of a converged
//! slot is ignored), and whether the forecast heads are consumed at all.
//! Backends that can exploit the plan skip the dead work: [`mock::MockArm`]
//! computes exactly the promised spans, and compiled executables route
//! through a [`crate::runtime::step::VariantCatalog`] that compacts live
//! rows into the smallest exported batch and picks the shortest exported
//! logp span covering the frontier hull. A lone shape-specialized
//! executable falls back to the full pass. Either way the outputs the
//! plan promises are bitwise identical, so the paper's exactness guarantee
//! is untouched — that invariant is what makes partial inference safe.

pub mod ancestral;
pub mod forecast;
pub mod fpi;
pub mod mock;
pub mod noise;
pub mod predictive;
pub mod trace;

use crate::runtime::step::{StepExecutable, StepOutput};
use anyhow::Result;

/// The span of one batch slot in a [`PassPlan`]: which row is live and
/// which flat positions of its log-prob output will actually be read.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SlotSpan {
    /// Whether this slot holds an unconverged job. Inactive rows may be
    /// skipped entirely — their outputs are never read.
    pub active: bool,
    /// First position whose log-probs the caller will read (the slot's
    /// frontier). Positions below it are already finalized: their outputs
    /// are immediately overwritten by the valid prefix and never read.
    pub lo: usize,
    /// One past the last position the caller will read (exclusive).
    /// `dim` for predictive passes; `lo + 1` for ancestral passes, which
    /// consume exactly one new position per call.
    pub hi: usize,
}

/// A frontier-aware work plan for one inference pass (the partial-inference
/// contract between the sampling loop and a [`StepModel`] backend).
///
/// Semantics: after `run_plan(x, out, plan)`, `out.logp` holds valid
/// log-probs for every active slot's `[lo, hi)` span. Everything else —
/// inactive rows, positions below `lo` / at or above `hi`, and `out.fore`
/// when `need_fore` is false — may be stale or unwritten, and the caller
/// must not read it. Backends are free to ignore the plan and compute the
/// full shape (the compiled PJRT executable does exactly that); a plan is
/// a permission to skip work, never an obligation.
#[derive(Clone, Debug, Default)]
pub struct PassPlan {
    /// Per-slot spans, length `batch()`.
    pub slots: Vec<SlotSpan>,
    /// Whether the forecast heads (`out.fore`) will be read after this
    /// pass. False for every policy except the learned forecaster.
    pub need_fore: bool,
    /// Whether the caller scans outputs past its first forecast
    /// disagreement (policies that reuse previous-pass outputs do; purely
    /// positional policies do not). Informational for backends that could
    /// stream outputs; row-skipping correctness never depends on it.
    pub need_full_scan: bool,
}

impl PassPlan {
    /// The conservative plan: every row live over the full dimension.
    pub fn full(batch: usize, dim: usize) -> PassPlan {
        PassPlan {
            slots: vec![SlotSpan { active: true, lo: 0, hi: dim }; batch],
            need_fore: true,
            need_full_scan: true,
        }
    }

    /// Log-prob positions this plan asks for (a full pass is
    /// `batch * dim`).
    pub fn positions(&self) -> usize {
        self.slots.iter().filter(|s| s.active).map(|s| s.hi.saturating_sub(s.lo)).sum()
    }

    /// Total K-length output rows this plan asks for: log-prob positions
    /// plus, when the heads are read, the forecast-head rows a backend
    /// must produce (pixels at or above each live slot's `lo / channels`
    /// query floor). The useful-work metric the hot-path bench records —
    /// a full pass is `batch * (dim + pixels * t_fore)`.
    pub fn rows(&self, pixels: usize, t_fore: usize, channels: usize) -> usize {
        let logp = self.positions();
        if !self.need_fore || t_fore == 0 {
            return logp;
        }
        let heads: usize = self
            .slots
            .iter()
            .filter(|s| s.active)
            .map(|s| (pixels - (s.lo / channels.max(1)).min(pixels)) * t_fore)
            .sum();
        logp + heads
    }

    /// Number of live rows.
    pub fn active_rows(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }
}

/// Abstraction over the ARM's parallel-inference pass. Implemented by the
/// compiled PJRT executable and by [`mock::MockArm`] for tests.
pub trait StepModel {
    fn batch(&self) -> usize;
    fn dim(&self) -> usize;
    fn categories(&self) -> usize;
    fn pixels(&self) -> usize;
    fn t_fore(&self) -> usize;
    /// Data channels per pixel (flat layout is channel-innermost).
    fn channels(&self) -> usize {
        self.dim() / self.pixels()
    }
    /// One parallel pass: x i32[B,d] -> logp [B,d,K], fore [B,P,T,K].
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()>;
    /// One pass restricted to the plan's live spans (see [`PassPlan`] for
    /// the staleness contract). Backends that cannot exploit partial
    /// inference fall back to the full-shape pass — results are bitwise
    /// identical either way on every position the plan promises.
    ///
    /// Returns the number of K-length output rows the backend *actually
    /// computed* — the same unit as [`PassPlan::rows`]. A full-shape
    /// fallback reports `batch * (dim + pixels * t_fore)` regardless of the
    /// plan; a plan-exploiting backend reports the plan's cost; a
    /// shape-variant catalog reports the device cost of the variant it
    /// selected. This is the ground truth `positions_evaluated` accounting
    /// is built from, so it must never be aspirational.
    fn run_plan(&self, x: &[i32], out: &mut StepOutput, _plan: &PassPlan) -> Result<usize> {
        self.run_into(x, out)?;
        Ok(self.batch() * (self.dim() + self.pixels() * self.t_fore()))
    }
    /// Whether `run_plan` can skip work the plan allows (informational —
    /// work accounting uses `run_plan`'s return value, which is exact even
    /// for backends that only partially exploit a plan).
    fn exploits_plan(&self) -> bool {
        false
    }
}

impl StepModel for StepExecutable {
    fn batch(&self) -> usize {
        self.batch
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn categories(&self) -> usize {
        self.categories
    }
    fn pixels(&self) -> usize {
        self.pixels
    }
    fn t_fore(&self) -> usize {
        self.t_fore
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> Result<()> {
        StepExecutable::run_into(self, x, out)
    }
}

/// Result of sampling one image/latent.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The sample, flat `[d]`.
    pub x: Vec<i32>,
    /// ARM passes until *this* job converged.
    pub iterations: usize,
    /// Per-variable: 1 if the forecast for that variable was wrong when it
    /// was finalized (the red pixels of Figs. 3-5).
    pub mistakes: Vec<u8>,
    /// Per-variable: the pass index (1-based) at which the variable's
    /// final value was determined (Fig. 6).
    pub converge_iter: Vec<u32>,
}

/// Result of sampling a batch.
#[derive(Clone, Debug)]
pub struct BatchResult {
    pub jobs: Vec<JobResult>,
    /// ARM passes for the whole batch — the slowest job determines this
    /// (paper §4.1's batched semantics).
    pub arm_calls: usize,
    pub wall_secs: f64,
}

impl BatchResult {
    /// ARM calls as a percentage of the baseline's d calls.
    pub fn calls_pct(&self, d: usize) -> f64 {
        100.0 * self.arm_calls as f64 / d as f64
    }
}
