//! Naive ancestral sampling (paper Eq. 2) — the baseline every table row
//! is normalized against: exactly `d` sequential ARM passes, one variable
//! finalized per pass. Pass `j` only ever reads position `j`'s log-probs,
//! so the passes run under single-position [`PassPlan`]s — a plan-aware
//! backend computes `d` positions total instead of `d²`, and no forecast
//! heads at all (the baseline never reads them).

use super::noise::JobNoise;
use super::{BatchResult, JobResult, PassPlan, SlotSpan, StepModel};
use crate::runtime::step::StepOutput;
use crate::substrate::gumbel::gumbel_argmax;
use crate::substrate::timer::Timer;
use anyhow::Result;

/// The pass-`j` plan: every slot live at exactly position `j`.
fn position_plan(plan: &mut PassPlan, j: usize) {
    for s in plan.slots.iter_mut() {
        s.lo = j;
        s.hi = j + 1;
    }
}

/// Sample one image with the d-call baseline (batch-1 view of the model;
/// for batched models only slot 0 is used).
pub fn ancestral_sample<M: StepModel>(model: &M, noise: &JobNoise) -> Result<JobResult> {
    let d = model.dim();
    let k = model.categories();
    let b = model.batch();
    let mut x = vec![0i32; b * d];
    let mut out = StepOutput::default();
    let mut plan = PassPlan { slots: vec![SlotSpan::default(); b], need_fore: false, need_full_scan: false };
    plan.slots[0].active = true;
    for j in 0..d {
        position_plan(&mut plan, j);
        model.run_plan(&x, &mut out, &plan)?;
        let lp = &out.logp[j * k..(j + 1) * k];
        x[j] = gumbel_argmax(lp, noise.row(j)) as i32;
    }
    Ok(JobResult {
        x: x[..d].to_vec(),
        iterations: d,
        mistakes: vec![1; d], // every variable needed its own pass
        converge_iter: (1..=d as u32).collect(),
    })
}

/// Baseline over a full batch: d passes, each finalizing position j for
/// every slot (the batch shares the pass, as on GPU).
pub fn ancestral_batch<M: StepModel>(model: &M, noises: &[JobNoise]) -> Result<BatchResult> {
    let d = model.dim();
    let k = model.categories();
    let b = model.batch();
    assert_eq!(noises.len(), b, "one noise block per slot");
    let mut x = vec![0i32; b * d];
    let mut out = StepOutput::default();
    let mut plan = PassPlan { slots: vec![SlotSpan { active: true, lo: 0, hi: 0 }; b], need_fore: false, need_full_scan: false };
    let timer = Timer::start();
    for j in 0..d {
        position_plan(&mut plan, j);
        model.run_plan(&x, &mut out, &plan)?;
        for (s, noise) in noises.iter().enumerate() {
            let lp = &out.logp[(s * d + j) * k..(s * d + j + 1) * k];
            x[s * d + j] = gumbel_argmax(lp, noise.row(j)) as i32;
        }
    }
    let wall = timer.secs();
    let jobs = (0..b)
        .map(|s| JobResult {
            x: x[s * d..(s + 1) * d].to_vec(),
            iterations: d,
            mistakes: vec![1; d],
            converge_iter: (1..=d as u32).collect(),
        })
        .collect();
    Ok(BatchResult { jobs, arm_calls: d, wall_secs: wall })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::mock::MockArm;

    #[test]
    fn baseline_uses_exactly_d_calls() {
        let model = MockArm::new(1, 2, 4, 3, 1, 2.0, 1);
        let noise = JobNoise::new(0, 0, model.dim(), 3);
        let r = ancestral_sample(&model, &noise).unwrap();
        assert_eq!(r.iterations, model.dim());
        assert!(r.x.iter().all(|&v| (0..3).contains(&v)));
    }

    #[test]
    fn batch_matches_single() {
        let m1 = MockArm::new(1, 2, 4, 3, 1, 2.0, 2);
        let m3 = MockArm::new(3, 2, 4, 3, 1, 2.0, 2);
        let d = m1.dim();
        let noises: Vec<JobNoise> = (0..3).map(|id| JobNoise::new(5, id, d, 3)).collect();
        let batch = ancestral_batch(&m3, &noises).unwrap();
        for (id, noise) in noises.iter().enumerate() {
            let single = ancestral_sample(&m1, noise).unwrap();
            assert_eq!(batch.jobs[id].x, single.x, "slot {id}");
        }
        assert_eq!(batch.arm_calls, d);
    }

    #[test]
    fn planned_baseline_matches_full_passes() {
        // The single-position plans must be invisible: same sample as a
        // literal full-pass ancestral loop.
        let model = MockArm::new(1, 2, 5, 4, 1, 2.0, 6);
        let d = model.dim();
        let k = model.categories();
        let noise = JobNoise::new(11, 0, d, k);
        let planned = ancestral_sample(&model, &noise).unwrap();
        let mut x = vec![0i32; d];
        let mut out = crate::runtime::step::StepOutput::default();
        for j in 0..d {
            model.run_into(&x, &mut out).unwrap();
            x[j] = crate::substrate::gumbel::gumbel_argmax(&out.logp[j * k..(j + 1) * k], noise.row(j)) as i32;
        }
        assert_eq!(planned.x, x, "planned baseline diverged from full-pass baseline");
    }

    #[test]
    fn deterministic_given_noise() {
        let model = MockArm::new(1, 3, 4, 4, 1, 3.0, 3);
        let noise = JobNoise::new(9, 0, model.dim(), 4);
        let a = ancestral_sample(&model, &noise).unwrap();
        let b = ancestral_sample(&model, &noise).unwrap();
        assert_eq!(a.x, b.x);
    }
}
