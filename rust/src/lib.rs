//! # predsamp — Predictive Sampling with Forecasting Autoregressive Models
//!
//! A rust serving stack reproducing Wiggers & Hoogeboom, *Predictive
//! Sampling with Forecasting Autoregressive Models*, ICML 2020.
//!
//! Architecture (see `docs/ARCHITECTURE.md` for the full layer diagram,
//! slot lifecycle, and `ServeConfig` knob map; `docs/PROTOCOL.md` for
//! the wire protocol):
//!
//! * [`runtime`] — loads the AOT-compiled JAX/Pallas model artifacts
//!   (`artifacts/*.hlo.txt`) onto the PJRT CPU client and exposes typed
//!   executables. Python never runs on the request path.
//! * [`sampler`] — the paper's contribution: predictive sampling
//!   (Algorithm 1), ARM fixed-point iteration (Algorithm 2), forecaster
//!   policies (zeros / predict-last / FPI / learned modules / ablations),
//!   and the Gumbel-max reparametrization that makes sampling a
//!   deterministic fixed-point problem.
//! * [`coordinator`] — the serving layer: engine, elastic
//!   continuous-batching scheduler (the paper's deferred "scheduling
//!   system" future work), pluggable sizing/admission policies, sharded
//!   work-stealing TCP server, metrics.
//! * [`substrate`] — offline-friendly building blocks (PRNG, Gumbel noise,
//!   JSON, stats, images, CLI, thread pool, property-test harness); this
//!   environment has no crates.io access beyond the `xla` closure.
//! * [`bench`] — criterion-lite harness + printers that regenerate every
//!   table and figure of the paper's evaluation section.
//! * [`analysis`] — `predsamp-lint`: repo-aware static analysis
//!   (`cargo run --bin lint`) machine-checking the exactness, unsafe-FFI,
//!   no-panic, lock-order, and doc-parity invariants
//!   (`docs/ANALYSIS.md`).

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod sampler;
pub mod substrate;

pub use coordinator::engine::Engine;
pub use runtime::artifact::Manifest;

/// Default artifacts directory, overridable via `PREDSAMP_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PREDSAMP_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd looking for artifacts/manifest.json (so examples,
    // tests and benches work from any directory inside the repo).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
