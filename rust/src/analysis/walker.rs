//! Deterministic file walker: collects the `.rs` sources under a repo
//! root's `rust/src/` tree, sorted by path so every lint run visits files
//! (and therefore reports findings) in the same order.

use super::source::SourceFile;
use std::fs;
use std::path::{Path, PathBuf};

/// All `.rs` files under `root/rust/src`, sorted, as repo-relative
/// forward-slash paths paired with their contents. Unreadable entries are
/// skipped (a file deleted mid-walk must not kill the linter).
pub fn rust_sources(root: &Path) -> Vec<SourceFile> {
    let src = root.join("rust").join("src");
    let mut paths = Vec::new();
    collect(&src, &mut paths);
    paths.sort();
    paths
        .into_iter()
        .filter_map(|p| {
            let text = fs::read_to_string(&p).ok()?;
            Some(SourceFile::from_source(relative_label(root, &p), text))
        })
        .collect()
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// `root`-relative path with forward slashes — the label passes scope on.
pub fn relative_label(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components().map(|c| c.as_os_str().to_string_lossy()).collect::<Vec<_>>().join("/")
}

/// Walk upward from `start` to the first directory containing
/// `Cargo.toml` — the repo root the lint binary analyzes.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("Cargo.toml").exists() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}
