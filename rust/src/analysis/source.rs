//! A lexed source file plus the repo-lint annotations parsed out of it:
//! `// lint:allow(<pass>): <reason>` escapes, `// SAFETY:` comments, and
//! the `#[cfg(test)]` / `#[test]` regions that non-test-only passes skip.

use super::lexer::{lex, Tok, TokKind};

/// One `lint:allow` escape annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    /// Line the annotation comment sits on.
    pub line: u32,
    /// The pass name inside the parentheses.
    pub pass: String,
    /// The written reason after the colon (may be empty — that is itself
    /// a finding, see the allow-hygiene check in [`crate::analysis::run_passes`]).
    pub reason: String,
}

/// A lexed file ready for the lint passes.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes (`rust/src/...`). Passes
    /// scope themselves by prefix-matching this.
    pub path: String,
    /// Raw text (doc-parity greps docs against it).
    pub text: String,
    /// Token stream, comments included.
    pub toks: Vec<Tok>,
    /// Parsed `lint:allow` annotations.
    pub allows: Vec<Allow>,
    test_ranges: Vec<(u32, u32)>,
}

impl SourceFile {
    /// Lex `text` under the given repo-relative path label. The label —
    /// not the filesystem location — is what passes scope on, so fixture
    /// tests can present a file as living anywhere in the tree.
    pub fn from_source(path: impl Into<String>, text: impl Into<String>) -> Self {
        let text = text.into();
        let toks = lex(&text);
        let allows = parse_allows(&toks);
        let test_ranges = find_test_ranges(&toks);
        SourceFile { path: path.into(), text, toks, allows, test_ranges }
    }

    /// Is `line` inside a `#[cfg(test)]` module/item or a `#[test]` fn?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// Is a finding for `pass` on `line` excused by a `lint:allow`
    /// annotation on the same line or the line directly above?
    pub fn allowed(&self, pass: &str, line: u32) -> bool {
        self.allows.iter().any(|a| a.pass == pass && (a.line == line || a.line + 1 == line))
    }

    /// Does a comment containing `SAFETY:` appear on `line` or within the
    /// `window` lines above it?
    pub fn has_safety_comment(&self, line: u32, window: u32) -> bool {
        let lo = line.saturating_sub(window);
        self.toks.iter().any(|t| t.is_comment() && t.text.contains("SAFETY:") && t.line >= lo && t.line <= line)
    }

    /// Indices of non-comment tokens, in order — the stream passes match
    /// identifier/punctuation sequences against.
    pub fn sig(&self) -> Vec<usize> {
        (0..self.toks.len()).filter(|&i| !self.toks[i].is_comment()).collect()
    }

    /// Token-index range (over [`Self::sig`] indices) of the brace-balanced
    /// body of `fn name`, excluding the braces themselves.
    pub fn fn_body(&self, name: &str) -> Option<(usize, usize)> {
        let sig = self.sig();
        let mut i = 0;
        while i + 1 < sig.len() {
            if self.toks[sig[i]].is_ident("fn") && self.toks[sig[i + 1]].is_ident(name) {
                // Skip to the opening brace (signatures contain no braces).
                let mut j = i + 2;
                while j < sig.len() && !self.toks[sig[j]].is_punct('{') {
                    if self.toks[sig[j]].is_punct(';') {
                        return None; // declaration without a body
                    }
                    j += 1;
                }
                let open = j;
                let mut depth = 0usize;
                while j < sig.len() {
                    if self.toks[sig[j]].is_punct('{') {
                        depth += 1;
                    } else if self.toks[sig[j]].is_punct('}') {
                        depth -= 1;
                        if depth == 0 {
                            return Some((open + 1, j));
                        }
                    }
                    j += 1;
                }
                return None;
            }
            i += 1;
        }
        None
    }

    /// `pub` field names (with their lines) of `struct name { ... }`.
    pub fn struct_fields(&self, name: &str) -> Vec<(String, u32)> {
        let sig = self.sig();
        let mut out = Vec::new();
        let mut i = 0;
        while i + 2 < sig.len() {
            if self.toks[sig[i]].is_ident("struct") && self.toks[sig[i + 1]].is_ident(name) && self.toks[sig[i + 2]].is_punct('{') {
                let mut depth = 1usize;
                let mut j = i + 3;
                while j < sig.len() && depth > 0 {
                    let t = &self.toks[sig[j]];
                    if t.is_punct('{') {
                        depth += 1;
                    } else if t.is_punct('}') {
                        depth -= 1;
                    } else if depth == 1 && t.is_ident("pub") {
                        if let (Some(n), Some(c)) = (sig.get(j + 1), sig.get(j + 2)) {
                            if self.toks[*c].is_punct(':') && self.toks[*n].kind == TokKind::Ident {
                                out.push((self.toks[*n].text.clone(), self.toks[*n].line));
                            }
                        }
                    }
                    j += 1;
                }
                return out;
            }
            i += 1;
        }
        out
    }
}

fn parse_allows(toks: &[Tok]) -> Vec<Allow> {
    let mut out = Vec::new();
    for t in toks {
        if !t.is_comment() {
            continue;
        }
        // The annotation must open the comment (`// lint:allow(...)`) —
        // a mention elsewhere in a sentence (like this one) is prose, not
        // an escape.
        let head = t.text.trim_start_matches(['/', '*', '!']).trim_start();
        let Some(rest) = head.strip_prefix("lint:allow") else { continue };
        let (pass, reason) = match rest.strip_prefix('(').and_then(|r| r.split_once(')')) {
            Some((p, tail)) => (p.trim().to_string(), tail.trim_start().strip_prefix(':').unwrap_or("").trim().to_string()),
            None => (String::new(), String::new()), // malformed — caught by allow hygiene
        };
        out.push(Allow { line: t.line, pass, reason });
    }
    out
}

/// Line ranges covered by `#[cfg(test)]` items and `#[test]` functions:
/// from the attribute to the close of the item's brace-balanced body (or
/// its terminating `;`).
fn find_test_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let sig: Vec<usize> = (0..toks.len()).filter(|&i| !toks[i].is_comment()).collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < sig.len() {
        if toks[sig[i]].is_punct('#') && sig.get(i + 1).is_some_and(|&j| toks[j].is_punct('[')) {
            // Collect the attribute's tokens up to the matching `]`.
            let start_line = toks[sig[i]].line;
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut words = Vec::new();
            while j < sig.len() {
                let t = &toks[sig[j]];
                if t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Ident {
                    words.push(t.text.as_str());
                }
                j += 1;
            }
            let is_test_attr =
                words.first() == Some(&"test") || (words.contains(&"cfg") && words.contains(&"test") && !words.contains(&"not"));
            if is_test_attr {
                // Mark through the end of the annotated item: first `;` at
                // brace depth 0, or the close of the first brace block.
                let mut k = j + 1;
                let mut bdepth = 0usize;
                let mut end_line = start_line;
                while k < sig.len() {
                    let t = &toks[sig[k]];
                    end_line = t.line;
                    if t.is_punct('{') {
                        bdepth += 1;
                    } else if t.is_punct('}') {
                        bdepth -= 1;
                        if bdepth == 0 {
                            break;
                        }
                    } else if t.is_punct(';') && bdepth == 0 {
                        break;
                    }
                    k += 1;
                }
                out.push((start_line, end_line));
                i = k + 1;
                continue;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    out
}
