//! Findings and the two report renderings: human `path:line` text for the
//! terminal, and machine-readable JSON for the CI artifact.

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Which pass produced it (`unsafe-audit`, `nondet-guard`, ...).
    pub pass: &'static str,
    /// Repo-relative file path.
    pub path: String,
    /// 1-indexed line.
    pub line: u32,
    /// Human-readable statement of the violation.
    pub msg: String,
}

impl Finding {
    /// Build a finding.
    pub fn new(pass: &'static str, path: &str, line: u32, msg: impl Into<String>) -> Self {
        Finding { pass, path: path.to_string(), line, msg: msg.into() }
    }
}

/// A full lint run: every finding plus scan metadata.
#[derive(Debug)]
pub struct Report {
    /// Findings in file/line order.
    pub findings: Vec<Finding>,
    /// How many source files were scanned.
    pub files_scanned: usize,
    /// Pass names that ran.
    pub passes: Vec<&'static str>,
}

impl Report {
    /// Sort findings by (path, line, pass) so output is deterministic.
    pub fn sort(&mut self) {
        self.findings.sort_by(|a, b| (&a.path, a.line, a.pass).cmp(&(&b.path, b.line, b.pass)));
    }

    /// Terminal rendering: one `path:line: [pass] message` per finding and
    /// a one-line summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.pass, f.msg));
        }
        out.push_str(&format!(
            "lint: {} finding{} across {} file{} ({} passes)\n",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.files_scanned,
            if self.files_scanned == 1 { "" } else { "s" },
            self.passes.len()
        ));
        out
    }

    /// JSON rendering for the CI artifact: `{"ok", "files_scanned",
    /// "passes", "findings": [{"pass", "path", "line", "msg"}]}`.
    pub fn render_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"ok\":{},", self.findings.is_empty()));
        out.push_str(&format!("\"files_scanned\":{},", self.files_scanned));
        out.push_str("\"passes\":[");
        out.push_str(&self.passes.iter().map(|p| format!("\"{p}\"")).collect::<Vec<_>>().join(","));
        out.push_str("],\"findings\":[");
        let items: Vec<String> = self
            .findings
            .iter()
            .map(|f| {
                format!("{{\"pass\":\"{}\",\"path\":\"{}\",\"line\":{},\"msg\":\"{}\"}}", f.pass, escape_json(&f.path), f.line, escape_json(&f.msg))
            })
            .collect();
        out.push_str(&items.join(","));
        out.push_str("]}");
        out.push('\n');
        out
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
