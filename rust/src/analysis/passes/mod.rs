//! The five project-specific lint passes, plus the allow-hygiene check
//! that keeps the escape hatch honest.

pub mod doc_parity;
pub mod lock_order;
pub mod nondet;
pub mod panic_guard;
pub mod unsafe_audit;

use super::report::Finding;
use super::source::SourceFile;
use std::path::Path;

/// Everything a pass gets to look at: the lexed sources plus the repo
/// root (for the docs files doc-parity reads).
pub struct Ctx<'a> {
    /// Lexed repo sources, sorted by path.
    pub files: &'a [SourceFile],
    /// Repo root directory.
    pub root: &'a Path,
}

/// The registered pass names, in execution order.
pub const PASS_NAMES: &[&str] =
    &[unsafe_audit::NAME, nondet::NAME, panic_guard::NAME, lock_order::NAME, doc_parity::NAME];

/// Run every pass plus allow hygiene; findings land in `out`.
pub fn run_all(ctx: &Ctx, out: &mut Vec<Finding>) {
    unsafe_audit::run(ctx, out);
    nondet::run(ctx, out);
    panic_guard::run(ctx, out);
    lock_order::run(ctx, out);
    doc_parity::run(ctx, out);
    allow_hygiene(ctx, out);
}

/// The escape hatch polices itself: every `lint:allow` must name a real
/// pass and carry a written reason. (Without this, escapes rot into
/// unexplained suppressions.)
pub fn allow_hygiene(ctx: &Ctx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        for a in &file.allows {
            if !PASS_NAMES.contains(&a.pass.as_str()) {
                out.push(Finding::new(
                    "allow-hygiene",
                    &file.path,
                    a.line,
                    format!("lint:allow names unknown pass {:?} (known: {})", a.pass, PASS_NAMES.join(", ")),
                ));
            } else if a.reason.is_empty() {
                out.push(Finding::new(
                    "allow-hygiene",
                    &file.path,
                    a.line,
                    format!("lint:allow({}) without a written reason — escapes must say why", a.pass),
                ));
            }
        }
    }
}
