//! **lock-discipline** — nested lock acquisitions follow the declared
//! order.
//!
//! The serving stack has a small, fixed set of mutexes; deadlock freedom
//! rests on every thread acquiring them in one global order. That order
//! is declared here as a manifest (field name → rank):
//!
//! | rank | lock field | owner |
//! |------|-----------|-------|
//! | 0 | `state`    | `EnginePool` — queues, routes, generation |
//! | 1 | `metrics`  | per-worker / dispatcher `Mutex<Metrics>` |
//! | 2 | `resident` | per-worker resident-model list |
//! | 3 | `inner`    | `ConvergenceBook` EWMA table |
//!
//! The pass tracks, *within one function body*, which manifest locks are
//! held — `let g = x.state.lock()...` holds `state` until `drop(g)` or
//! the end of `g`'s enclosing block; an unbound `x.metrics.lock()...`
//! holds `metrics` until the end of the statement — and flags any
//! acquisition of a lock ranked **above** one already held (e.g. taking
//! `state` while holding `metrics`).
//!
//! Known limits, by design (this is a lexical tool, not a borrow
//! checker): tracking is intraprocedural, so a helper that locks `state`
//! called while `metrics` is held is not seen; guards stored into structs
//! are treated as dropped at end of statement; `Condvar::wait_timeout`
//! consuming and re-yielding a guard under the same name is treated as
//! the same hold. The fixture tests pin the supported shapes.

use crate::analysis::lexer::TokKind;
use crate::analysis::passes::Ctx;
use crate::analysis::report::Finding;
use crate::analysis::source::SourceFile;

/// Pass name, as used in `lint:allow(...)`.
pub const NAME: &str = "lock-discipline";

/// Lock-order manifest: acquiring `MANIFEST[i]` while holding
/// `MANIFEST[j]` for `j > i` is a violation.
pub const MANIFEST: &[&str] = &["state", "metrics", "resident", "inner"];

/// Modules the discipline applies to (where the manifest locks live).
pub const SCOPED_MODULES: &[&str] = &["rust/src/coordinator/server/", "rust/src/coordinator/policy.rs"];

fn rank(name: &str) -> Option<usize> {
    MANIFEST.iter().position(|&m| m == name)
}

#[derive(Debug)]
struct Held {
    rank: usize,
    /// `let` binding name the guard lives in, if any.
    guard: Option<String>,
    /// Brace depth at binding time — popped when the block closes.
    depth: usize,
    /// Unbound temporaries are released at the end of the statement.
    stmt_scoped: bool,
}

/// Run the pass.
pub fn run(ctx: &Ctx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        if !SCOPED_MODULES.iter().any(|m| file.path.starts_with(m)) {
            continue;
        }
        scan_file(file, out);
    }
}

fn scan_file(file: &SourceFile, out: &mut Vec<Finding>) {
    let sig = file.sig();
    let mut held: Vec<Held> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_start = 0usize; // sig index where the current statement began
    let mut k = 0usize;
    while k < sig.len() {
        let t = &file.toks[sig[k]];
        match t.kind {
            TokKind::Punct('{') => {
                depth += 1;
                stmt_start = k + 1;
            }
            TokKind::Punct('}') => {
                depth = depth.saturating_sub(1);
                // A function body closed: its bindings die with it.
                held.retain(|h| h.depth <= depth);
                // Treat a fully-closed file region as a hard reset so one
                // function's unmatched braces cannot leak holds into the next.
                if depth == 0 {
                    held.clear();
                }
                stmt_start = k + 1;
            }
            TokKind::Punct(';') => {
                held.retain(|h| !h.stmt_scoped);
                stmt_start = k + 1;
            }
            TokKind::Ident => {
                // drop(guard) releases the named hold.
                if t.text == "drop" && matches(file, &sig, k + 1, &["("]) {
                    if let Some(g) = sig.get(k + 2).map(|&j| &file.toks[j]) {
                        if g.kind == TokKind::Ident {
                            held.retain(|h| h.guard.as_deref() != Some(g.text.as_str()));
                        }
                    }
                }
                // An acquisition: `<manifest-name> . lock (`.
                if let Some(r) = rank(&t.text) {
                    if matches(file, &sig, k + 1, &[".", "lock", "("]) {
                        if !file.in_test(t.line) && !file.allowed(NAME, t.line) {
                            for h in &held {
                                if h.rank > r {
                                    out.push(Finding::new(
                                        NAME,
                                        &file.path,
                                        t.line,
                                        format!(
                                            "lock `{}` acquired while `{}` is held — declared order is {}",
                                            t.text,
                                            MANIFEST[h.rank],
                                            MANIFEST.join(" -> ")
                                        ),
                                    ));
                                }
                            }
                        }
                        let guard = let_binding(file, &sig, stmt_start, k);
                        held.push(Held { rank: r, stmt_scoped: guard.is_none(), guard, depth });
                    }
                }
            }
            _ => {}
        }
        k += 1;
    }
}

/// Does the token at `sig[k]` start this sequence of idents/puncts?
fn matches(file: &SourceFile, sig: &[usize], k: usize, pat: &[&str]) -> bool {
    pat.iter().enumerate().all(|(i, p)| {
        sig.get(k + i).is_some_and(|&j| {
            let t = &file.toks[j];
            match t.kind {
                TokKind::Punct(c) => p.len() == 1 && p.starts_with(c),
                TokKind::Ident => t.text == *p,
                _ => false,
            }
        })
    })
}

/// If the statement beginning at `sig[stmt_start]` is `let [mut] NAME = ...`
/// (or `let (NAME, ...) = ...`), the guard binding name.
fn let_binding(file: &SourceFile, sig: &[usize], stmt_start: usize, upto: usize) -> Option<String> {
    if stmt_start >= upto {
        return None;
    }
    let first = &file.toks[*sig.get(stmt_start)?];
    if !first.is_ident("let") {
        return None;
    }
    let mut k = stmt_start + 1;
    if file.toks[*sig.get(k)?].is_punct('(') {
        k += 1; // tuple pattern: take the first element as the guard name
    }
    if file.toks[*sig.get(k)?].is_ident("mut") {
        k += 1;
    }
    let name = &file.toks[*sig.get(k)?];
    (name.kind == TokKind::Ident).then(|| name.text.clone())
}
