//! **nondet-guard** — nothing nondeterministic in the exactness-critical
//! modules.
//!
//! The repo's load-bearing invariant is bitwise exactness: noise is keyed
//! by `(seed, job index)` and every serving configuration must produce
//! identical bytes. This pass bans the lexical sources of hidden
//! nondeterminism on the modules whose state can reach sampled or
//! serialized output:
//!
//! * `HashMap` / `HashSet` — iteration order varies run to run; use
//!   `BTreeMap` / `BTreeSet` or sort before anything observable.
//! * `Instant::now` / `SystemTime::now` — wall-clock reads.
//! * ambient RNG identifiers (`thread_rng`, `from_entropy`, `random`) —
//!   all noise must come from the seeded substrate PRNG.
//!
//! Test code (`#[cfg(test)]` / `#[test]`) is exempt; deliberate uses are
//! escaped inline with `// lint:allow(nondet-guard): <reason>`.

use crate::analysis::passes::Ctx;
use crate::analysis::report::Finding;

/// Pass name, as used in `lint:allow(...)`.
pub const NAME: &str = "nondet-guard";

/// Exactness-critical path prefixes (a trailing `/` scopes a directory).
pub const CRITICAL_MODULES: &[&str] = &[
    "rust/src/sampler/",
    "rust/src/coordinator/scheduler.rs",
    "rust/src/coordinator/policy.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/server/pool.rs",
    "rust/src/coordinator/server/feed.rs",
];

const BANNED_TYPES: &[&str] = &["HashMap", "HashSet"];
const BANNED_CLOCKS: &[&str] = &["Instant", "SystemTime"];
const BANNED_RNG: &[&str] = &["thread_rng", "from_entropy", "random"];

/// Run the pass.
pub fn run(ctx: &Ctx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        if !CRITICAL_MODULES.iter().any(|m| file.path.starts_with(m)) {
            continue;
        }
        let sig = file.sig();
        for (k, &i) in sig.iter().enumerate() {
            let t = &file.toks[i];
            if t.kind != crate::analysis::lexer::TokKind::Ident || file.in_test(t.line) || file.allowed(NAME, t.line) {
                continue;
            }
            if BANNED_TYPES.contains(&t.text.as_str()) {
                out.push(Finding::new(
                    NAME,
                    &file.path,
                    t.line,
                    format!("`{}` in an exactness-critical module — iteration order is nondeterministic; use BTree{} or sort", t.text, &t.text[4..]),
                ));
            } else if BANNED_CLOCKS.contains(&t.text.as_str()) && is_path_call(file, &sig, k, "now") {
                out.push(Finding::new(
                    NAME,
                    &file.path,
                    t.line,
                    format!("`{}::now` in an exactness-critical module — wall-clock reads cannot feed exact output", t.text),
                ));
            } else if BANNED_RNG.contains(&t.text.as_str()) {
                out.push(Finding::new(
                    NAME,
                    &file.path,
                    t.line,
                    format!("`{}` in an exactness-critical module — all noise must come from the seeded substrate PRNG", t.text),
                ));
            }
        }
    }
}

/// Does `sig[k]` start the token sequence `X :: method`?
fn is_path_call(file: &crate::analysis::source::SourceFile, sig: &[usize], k: usize, method: &str) -> bool {
    k + 3 < sig.len()
        && file.toks[sig[k + 1]].is_punct(':')
        && file.toks[sig[k + 2]].is_punct(':')
        && file.toks[sig[k + 3]].is_ident(method)
}
