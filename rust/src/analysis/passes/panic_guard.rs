//! **panic-guard** — no panics where a panic kills a shard or a worker.
//!
//! A panicking `.unwrap()` in a connection-plane event loop takes every
//! connection on that shard down with it; one in an engine-worker loop
//! strands the worker's queued groups. In those modules, errors must be
//! handled as degraded modes (log + error reply + keep serving), so this
//! pass bans `.unwrap()`, `.expect(...)`, and `panic!` in non-test code.
//!
//! Deliberately *not* banned: `unwrap_or`, `unwrap_or_else`,
//! `unwrap_or_default` (they are the degraded handling — the poison
//! recovery idiom is `.lock().unwrap_or_else(|e| e.into_inner())`),
//! `unreachable!` (a statically-argued invariant, reviewed case by case),
//! and anything under `#[cfg(test)]`.

use crate::analysis::passes::Ctx;
use crate::analysis::report::Finding;

/// Pass name, as used in `lint:allow(...)`.
pub const NAME: &str = "panic-guard";

/// Modules where a panic is an availability incident, not a bug report.
/// The federation router counts: a panic in its route loop or a backend
/// reader thread takes the whole front tier's fleet state down.
pub const GUARDED_MODULES: &[&str] =
    &["rust/src/coordinator/server/", "rust/src/coordinator/federation.rs", "rust/src/substrate/readiness.rs"];

/// Run the pass.
pub fn run(ctx: &Ctx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        if !GUARDED_MODULES.iter().any(|m| file.path.starts_with(m)) {
            continue;
        }
        let sig = file.sig();
        for (k, &i) in sig.iter().enumerate() {
            let t = &file.toks[i];
            if file.in_test(t.line) || file.allowed(NAME, t.line) {
                continue;
            }
            let method_call = |name: &str| {
                k > 0
                    && file.toks[sig[k - 1]].is_punct('.')
                    && t.is_ident(name)
                    && sig.get(k + 1).is_some_and(|&j| file.toks[j].is_punct('('))
            };
            if method_call("unwrap") || method_call("expect") {
                out.push(Finding::new(
                    NAME,
                    &file.path,
                    t.line,
                    format!("`.{}(...)` in a shard/worker loop — a panic here kills the shard; handle degraded instead", t.text),
                ));
            } else if t.is_ident("panic") && sig.get(k + 1).is_some_and(|&j| file.toks[j].is_punct('!')) {
                out.push(Finding::new(NAME, &file.path, t.line, "`panic!` in a shard/worker loop — handle degraded instead"));
            }
        }
    }
}
