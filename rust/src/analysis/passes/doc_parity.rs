//! **doc-parity** — the documented surface and the code surface are the
//! same surface.
//!
//! Three checks, replacing the sed/grep gate that used to live inline in
//! `ci.yml`:
//!
//! 1. Every config-struct field — `ServeConfig` in
//!    `rust/src/coordinator/config.rs` and `RouterConfig` in
//!    `rust/src/coordinator/federation.rs` — appears backticked in
//!    `docs/ARCHITECTURE.md`'s knob tables.
//! 2. Every such field is actually parsed by the CLI — it must appear
//!    as an identifier in `rust/src/main.rs` (the `serve`/`route` arms
//!    build their structs field-by-field, so a field the CLI forgot
//!    shows up as a missing identifier, not a silent default).
//! 3. Every `metrics`/`edge`/`fleet` key the server or router can emit
//!    — string keys in `Metrics::snapshot`, `Metrics::worker_value`
//!    (`metrics.rs`), `EdgeStats::value` (`conn.rs`),
//!    `metrics_response` (`mod.rs`), `catalog_value` (`engine.rs`, the
//!    shape-variant catalog telemetry object), and `fleet_value` /
//!    `router_metrics_response` (`federation.rs`) — appears in
//!    `docs/PROTOCOL.md`, quoted or backticked.
//!
//! Key extraction is lexical: a string literal directly after `(` and
//! followed by `,` (the `("key", Value::...)` tuple idiom) or directly
//! after `insert(` (the `obj.insert("key".into(), ...)` idiom), scanned
//! only inside the named function bodies.

use crate::analysis::lexer::TokKind;
use crate::analysis::passes::Ctx;
use crate::analysis::report::Finding;
use crate::analysis::source::SourceFile;
use std::fs;

/// Pass name, as used in `lint:allow(...)`.
pub const NAME: &str = "doc-parity";

const MAIN: &str = "rust/src/main.rs";
/// (file, config struct whose fields the knob tables and CLI must cover)
const CONFIG_SOURCES: &[(&str, &str)] = &[
    ("rust/src/coordinator/config.rs", "ServeConfig"),
    ("rust/src/coordinator/federation.rs", "RouterConfig"),
];
/// (file, functions whose bodies emit metrics/edge/fleet keys)
const KEY_SOURCES: &[(&str, &[&str])] = &[
    ("rust/src/coordinator/metrics.rs", &["snapshot", "worker_value"]),
    ("rust/src/coordinator/server/conn.rs", &["value"]),
    ("rust/src/coordinator/server/mod.rs", &["metrics_response"]),
    ("rust/src/coordinator/engine.rs", &["catalog_value"]),
    ("rust/src/coordinator/federation.rs", &["fleet_value", "router_metrics_response"]),
];

/// Run the pass.
pub fn run(ctx: &Ctx, out: &mut Vec<Finding>) {
    let find = |path: &str| ctx.files.iter().find(|f| f.path == path);

    let arch = fs::read_to_string(ctx.root.join("docs/ARCHITECTURE.md")).unwrap_or_default();
    let proto = fs::read_to_string(ctx.root.join("docs/PROTOCOL.md")).unwrap_or_default();

    // 1 + 2: config-struct fields vs knob tables and CLI.
    let main_idents: Vec<&str> = find(MAIN)
        .map(|m| m.toks.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect())
        .unwrap_or_default();
    for &(path, strukt) in CONFIG_SOURCES {
        let Some(cfg) = find(path) else {
            out.push(Finding::new(NAME, path, 1, format!("{strukt} source not found — doc-parity is blind")));
            continue;
        };
        let fields = cfg.struct_fields(strukt);
        if fields.is_empty() {
            out.push(Finding::new(NAME, path, 1, format!("could not extract any {strukt} fields — doc-parity is blind")));
        }
        for (field, line) in fields {
            if cfg.allowed(NAME, line) {
                continue;
            }
            if !arch.contains(&format!("`{field}`")) {
                out.push(Finding::new(NAME, path, line, format!("{strukt}::{field} is not documented in docs/ARCHITECTURE.md's knob table")));
            }
            if !main_idents.contains(&field.as_str()) {
                out.push(Finding::new(NAME, path, line, format!("{strukt}::{field} is never parsed by the CLI (rust/src/main.rs)")));
            }
        }
    }

    // 3: emitted metrics/edge keys vs PROTOCOL.md.
    for &(path, fns) in KEY_SOURCES {
        let Some(file) = find(path) else {
            out.push(Finding::new(NAME, path, 1, "metrics key source not found — doc-parity is blind"));
            continue;
        };
        for &func in fns {
            let Some((lo, hi)) = file.fn_body(func) else {
                out.push(Finding::new(NAME, path, 1, format!("fn {func} not found — doc-parity is blind")));
                continue;
            };
            for (key, line) in emitted_keys(file, lo, hi) {
                if file.allowed(NAME, line) {
                    continue;
                }
                if !proto.contains(&format!("\"{key}\"")) && !proto.contains(&format!("`{key}`")) {
                    let msg = format!("metrics key \"{key}\" (emitted by {func}) is not documented in docs/PROTOCOL.md");
                    out.push(Finding::new(NAME, path, line, msg));
                }
            }
        }
    }
}

/// String keys emitted between sig-token indices `lo..hi`: `("key",` and
/// `insert("key"` patterns.
fn emitted_keys(file: &SourceFile, lo: usize, hi: usize) -> Vec<(String, u32)> {
    let sig = file.sig();
    let mut out = Vec::new();
    for k in lo..hi {
        let t = &file.toks[sig[k]];
        if t.kind != TokKind::Str {
            continue;
        }
        let prev = k.checked_sub(1).map(|p| &file.toks[sig[p]]);
        let prev2 = k.checked_sub(2).map(|p| &file.toks[sig[p]]);
        let next = sig.get(k + 1).map(|&j| &file.toks[j]);
        // Keys are snake_case identifiers; that excludes format strings
        // and message literals that also sit in `(... ,` position.
        let key_shaped = !t.text.is_empty() && t.text.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
        let tuple_key = prev.is_some_and(|p| p.is_punct('(')) && next.is_some_and(|n| n.is_punct(','));
        let insert_key = prev.is_some_and(|p| p.is_punct('(')) && prev2.is_some_and(|p| p.is_ident("insert"));
        if key_shaped && (tuple_key || insert_key) {
            out.push((t.text.clone(), t.line));
        }
    }
    out
}
