//! **unsafe-audit** — `unsafe` is quarantined and justified.
//!
//! Two rules, both over real `unsafe` tokens only (the lexer guarantees
//! occurrences inside strings and comments never match):
//!
//! 1. `unsafe` may appear only in the allowlisted FFI modules — today
//!    exactly `rust/src/substrate/readiness.rs` (raw epoll/eventfd).
//!    Growing the allowlist is a reviewed change to this file.
//! 2. Every `unsafe` token must have a `// SAFETY:` comment on its line
//!    or within the three lines above, stating the invariant the block
//!    relies on.

use crate::analysis::passes::Ctx;
use crate::analysis::report::Finding;

/// Pass name, as used in `lint:allow(...)`.
pub const NAME: &str = "unsafe-audit";

/// Modules where `unsafe` is permitted at all.
pub const ALLOWED_MODULES: &[&str] = &["rust/src/substrate/readiness.rs"];

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit.
const SAFETY_WINDOW: u32 = 3;

/// Run the pass.
pub fn run(ctx: &Ctx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        for &i in &file.sig() {
            let t = &file.toks[i];
            if !t.is_ident("unsafe") {
                continue;
            }
            if file.allowed(NAME, t.line) {
                continue;
            }
            if !ALLOWED_MODULES.contains(&file.path.as_str()) {
                out.push(Finding::new(
                    NAME,
                    &file.path,
                    t.line,
                    format!("`unsafe` outside the allowlisted FFI modules ({})", ALLOWED_MODULES.join(", ")),
                ));
                continue;
            }
            if !file.has_safety_comment(t.line, SAFETY_WINDOW) {
                out.push(Finding::new(NAME, &file.path, t.line, "`unsafe` without a `// SAFETY:` comment stating its invariant"));
            }
        }
    }
}
