//! A lightweight Rust lexer for static analysis.
//!
//! This is *not* a full Rust lexer — it is exactly enough tokenizer to
//! make lexical lint passes sound: identifiers never match inside string
//! literals, `unsafe` inside a doc comment is a comment token, nested
//! block comments terminate where rustc says they do, and `'a` (lifetime)
//! is distinguished from `'a'` (char literal). Everything the passes key
//! on — identifier sequences, punctuation, comment text — survives with
//! line numbers attached; everything else (numeric suffixes, keyword
//! classification) is deliberately left coarse.

/// What kind of lexeme a [`Tok`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `HashMap`, `foo`).
    Ident,
    /// Numeric literal (coarse: digits plus trailing alphanumerics).
    Num,
    /// String literal — plain, raw, byte, or raw-byte. Text excludes quotes.
    Str,
    /// Character literal, escapes included (text excludes quotes).
    Char,
    /// Lifetime such as `'a` (text excludes the tick).
    Lifetime,
    /// `//`-style comment; text is everything after the slashes, trimmed.
    LineComment,
    /// `/* */`-style comment (nesting handled); text excludes delimiters.
    BlockComment,
    /// Single punctuation character (`.`, `:`, `{`, `!`, ...).
    Punct(char),
}

/// One token with its 1-indexed source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Lexeme class.
    pub kind: TokKind,
    /// Lexeme text (see [`TokKind`] for what is included per kind).
    pub text: String,
    /// 1-indexed line the token *starts* on.
    pub line: u32,
}

impl Tok {
    /// True if this token is an identifier with exactly this text.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// True if this token is the given punctuation character.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }

    /// True for either comment kind.
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// Tokenize `src`. Never fails: unterminated literals and stray bytes
/// degrade to best-effort tokens so a half-edited file still lints.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    out: Vec<Tok>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokKind, text: String, line: u32) {
        self.out.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Vec<Tok> {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(line),
                '/' if self.peek(1) == Some('*') => self.block_comment(line),
                '"' => self.string(line),
                '\'' => self.char_or_lifetime(line),
                'r' | 'b' if self.string_prefix_len() > 0 => {
                    let skip = self.string_prefix_len();
                    let raw = (0..skip).any(|i| self.peek(i) == Some('r'));
                    for _ in 0..skip {
                        self.bump();
                    }
                    if raw {
                        self.raw_string(line); // raw strings have no escapes, `#`-delimited or not
                    } else {
                        self.string(line); // b"..." escapes like a plain string
                    }
                }
                c if c.is_alphabetic() || c == '_' => self.ident(line),
                c if c.is_ascii_digit() => self.number(line),
                _ => {
                    self.bump();
                    self.push(TokKind::Punct(c), c.to_string(), line);
                }
            }
        }
        self.out
    }

    /// Length of a string-literal prefix (`r`, `b`, `br`, `rb`) at the
    /// cursor, counting only the letters — 0 if the letters start a plain
    /// identifier instead. `r#"` raw strings keep their hashes for
    /// [`raw_string`] to count.
    fn string_prefix_len(&self) -> usize {
        let mut n = 0;
        while let Some(c) = self.peek(n) {
            if (c == 'r' || c == 'b') && n < 2 {
                n += 1;
            } else {
                break;
            }
        }
        let mut after = n;
        let saw_raw = (0..n).any(|i| self.peek(i) == Some('r'));
        if saw_raw {
            while self.peek(after) == Some('#') {
                after += 1;
            }
        }
        if n > 0 && self.peek(after) == Some('"') && (saw_raw || after == n) {
            n
        } else {
            0
        }
    }

    fn line_comment(&mut self, line: u32) {
        self.bump();
        self.bump();
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(TokKind::LineComment, text.trim().to_string(), line);
    }

    fn block_comment(&mut self, line: u32) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                self.bump();
                self.bump();
                depth -= 1;
                if depth == 0 {
                    break;
                }
                text.push_str("*/");
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(TokKind::BlockComment, text.trim().to_string(), line);
    }

    fn string(&mut self, line: u32) {
        self.bump(); // opening quote
        let mut text = String::new();
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    text.push(c);
                    if let Some(e) = self.bump() {
                        text.push(e);
                    }
                }
                '"' => break,
                _ => text.push(c),
            }
        }
        self.push(TokKind::Str, text, line);
    }

    fn raw_string(&mut self, line: u32) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        let mut text = String::new();
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                // A raw string ends at `"` followed by exactly `hashes` `#`s.
                for i in 0..hashes {
                    if self.peek(i) != Some('#') {
                        text.push('"');
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            text.push(c);
        }
        self.push(TokKind::Str, text, line);
    }

    fn char_or_lifetime(&mut self, line: u32) {
        self.bump(); // tick
        match self.peek(0) {
            Some('\\') => {
                // Escaped char literal: consume through the closing tick.
                let mut text = String::new();
                while let Some(c) = self.bump() {
                    if c == '\\' {
                        text.push(c);
                        if let Some(e) = self.bump() {
                            text.push(e);
                        }
                    } else if c == '\'' {
                        break;
                    } else {
                        text.push(c);
                    }
                }
                self.push(TokKind::Char, text, line);
            }
            Some(c) if c.is_alphanumeric() || c == '_' => {
                let mut name = String::new();
                let mut n = 0;
                while let Some(k) = self.peek(n) {
                    if k.is_alphanumeric() || k == '_' {
                        name.push(k);
                        n += 1;
                    } else {
                        break;
                    }
                }
                if self.peek(n) == Some('\'') {
                    // 'x' — char literal (single scalar, then closing tick).
                    self.bump();
                    for _ in 0..n {
                        self.bump();
                    }
                    self.push(TokKind::Char, name, line);
                } else {
                    // 'a — lifetime (no closing tick).
                    for _ in 0..n {
                        self.bump();
                    }
                    self.push(TokKind::Lifetime, name, line);
                }
            }
            Some(other) => {
                // `'{' `-style single-char literal with punctuation inside.
                self.bump();
                if self.peek(0) == Some('\'') {
                    self.bump();
                }
                self.push(TokKind::Char, other.to_string(), line);
            }
            None => self.push(TokKind::Punct('\''), "'".to_string(), line),
        }
    }

    fn ident(&mut self, line: u32) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Ident, text, line);
    }

    fn number(&mut self, line: u32) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Num, text, line);
    }
}
