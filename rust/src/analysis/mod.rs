//! # `predsamp-lint` — repo-aware static analysis
//!
//! The repo's invariants (bitwise exactness, quarantined `unsafe`,
//! panic-free shard/worker loops, one global lock order, docs that match
//! the code) were policed dynamically — by A/B tests — or by eyeball.
//! This module encodes them as five lexical lint passes that run offline
//! with zero dependencies beyond `std`, via `cargo run --bin lint`:
//!
//! * [`passes::unsafe_audit`] — `unsafe` only in allowlisted FFI modules,
//!   every site justified by a `// SAFETY:` comment.
//! * [`passes::nondet`] — no `HashMap`/`HashSet`, wall-clock reads, or
//!   ambient RNG in exactness-critical modules.
//! * [`passes::panic_guard`] — no `.unwrap()`/`.expect(...)`/`panic!` in
//!   the connection plane or worker loops.
//! * [`passes::lock_order`] — nested acquisitions respect the declared
//!   lock-order manifest.
//! * [`passes::doc_parity`] — `ServeConfig` fields are in the
//!   ARCHITECTURE.md knob table *and* parsed by the CLI; emitted
//!   `metrics`/`edge` keys are in PROTOCOL.md.
//!
//! Deliberate violations are escaped inline with
//! `// lint:allow(<pass>): <reason>` on or directly above the offending
//! line; the allow-hygiene check rejects escapes with no written reason.
//! `docs/ANALYSIS.md` documents each pass, the escape grammar, and how
//! to add a pass.
//!
//! The machinery is deliberately layered so fixture tests can drive each
//! piece alone: [`lexer`] (tokens that never match inside strings or
//! comments), [`source`] (a lexed file plus its annotations and test
//! regions), [`walker`] (deterministic file discovery), [`passes`] (the
//! rules), [`report`] (text + JSON rendering).

pub mod lexer;
pub mod passes;
pub mod report;
pub mod source;
pub mod walker;

use passes::Ctx;
use report::Report;
use std::path::Path;

/// Lint the repo rooted at `root`: walk `rust/src`, run every pass, and
/// return the sorted report.
pub fn lint_repo(root: &Path) -> Report {
    let files = walker::rust_sources(root);
    let mut findings = Vec::new();
    passes::run_all(&Ctx { files: &files, root }, &mut findings);
    let mut report = Report { findings, files_scanned: files.len(), passes: passes::PASS_NAMES.to_vec() };
    report.sort();
    report
}
