//! predsamp CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   info                          list models from the artifact manifest
//!   eval    --model M             test-set bits/dim through the artifact
//!   sample  --model M --method X  sample a batch, print stats (+ppm)
//!   serve   --addr HOST:PORT      TCP serving (line-delimited JSON)
//!   route   --backend HOST:PORT   front-tier fleet router over N servers
//!   client  --addr --json '...'   one-shot request against a server
//!   table1|table2|table3          regenerate the paper's tables
//!   fig3|fig4|fig5|fig6           regenerate the paper's figures
//!   schedule-ablation             continuous vs synchronous batching

use anyhow::{anyhow, bail, ensure, Result};
use predsamp::bench::{figures, tables};
use predsamp::coordinator::config::{Method, ServeConfig};
use predsamp::coordinator::engine::Engine;
use predsamp::coordinator::federation::{self, RouterConfig};
use predsamp::coordinator::placement::PlacementKind;
use predsamp::coordinator::policy::{AdmissionKind, PolicyKind};
use predsamp::coordinator::scheduler;
use predsamp::coordinator::server;
use predsamp::runtime::artifact::Manifest;
use predsamp::sampler::forecast;
use predsamp::substrate::cli::Args;
use predsamp::substrate::readiness::ReadinessKind;
use predsamp::substrate::timer::fmt_duration;

const USAGE: &str = "predsamp — Predictive Sampling with Forecasting Autoregressive Models (ICML 2020)

USAGE: predsamp <command> [flags]

COMMANDS
  info                               list models in the artifact manifest
  eval     --model M                 bits/dim of M's test batch via the compiled artifact
  sample   --model M [--method fpi|baseline|zeros|last|forecast|noreparam]
           [--batch N] [--seed S] [--t-use T] [--ppm out.ppm]
  serve    [--addr 127.0.0.1:7199] [--max-batch 32] [--max-wait-ms 20] [--sync]
           [--engine-threads 2] [--conn-threads 1] [--readiness auto|scan|epoll]
           [--no-elastic] [--no-steal]
           [--policy occupancy|latency|slo] [--slo-ms 50] [--absorb-budget N]
           [--placement replicate|pinned|capped] [--pin model=0,2 ...]
           [--max-engines N] [--reply-timeout-ms 600000] [--max-line-len BYTES]
           [--outbound-cap BYTES] [--rate-limit REQ_PER_S] [--max-conns N]
           [--no-stream] [--no-frame] [--no-variants]
  route    --backend HOST:PORT [--backend ...] [--addr 127.0.0.1:7190]
           [--fleet-placement replicate|pinned|capped] [--fleet-pin model=0,2 ...]
           [--fleet-max-backends N] [--probe-interval-ms 200] [--probe-timeout-ms 1000]
           [--probe-fails 3] [--max-hops 4] [--conn-threads 1]
           [--readiness auto|scan|epoll] [--reply-timeout-ms 600000]
           [--max-line-len BYTES] [--outbound-cap BYTES] [--rate-limit REQ_PER_S]
           [--max-conns N]
  client   [--addr ...] --json '{\"op\":\"ping\"}' [--stream]
  table1 | table2 | table3           [--seeds K] [--batches 1,32] [--models a,b]
  fig3 | fig4 | fig5 | fig6          [--seed 10] [--out results/]
  schedule-ablation                  [--model M] [--jobs N] [--seed S]

Artifacts are found via ./artifacts or $PREDSAMP_ARTIFACTS (built by the
python AOT path under python/compile/); without them, `serve` and the
serving demo fall back to pure-rust mock models.";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        println!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1));
    if let Err(e) = run(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn manifest() -> Result<Manifest> {
    Manifest::load(predsamp::artifacts_dir())
}

fn seeds_of(args: &Args) -> Vec<u64> {
    let n = args.num::<usize>("seeds", 3);
    (0..n as u64).collect()
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => {
            let man = manifest()?;
            println!("artifacts: {} (quick={})", man.dir.display(), man.quick);
            println!("{:<16} {:>6} {:>6} {:>5} {:>8} {:<9} {:>7}", "model", "dim", "K", "T", "bpd", "kind", "batches");
            for m in man.models.values() {
                println!(
                    "{:<16} {:>6} {:>6} {:>5} {:>8.3} {:<9} {:?}",
                    m.name,
                    m.dim,
                    m.categories,
                    m.t_fore,
                    m.bpd,
                    format!("{:?}", m.kind),
                    m.step_batch_sizes()
                );
            }
            for a in man.autoencoders.values() {
                println!(
                    "ae:{:<14} img {}x{}  latent {}x{}x{} K={} mse={:.5}",
                    a.name, a.img_size, a.img_size, a.latent_channels, a.latent_hw, a.latent_hw, a.categories, a.mse
                );
            }
            args.finish().map_err(|e| anyhow!(e))
        }
        "eval" => {
            let man = manifest()?;
            let model = args.get("model", "mnist_bin");
            let engine = Engine::load(&man, &model)?;
            let bpd = engine.eval_bpd()?;
            println!("{model}: {bpd:.4} bits/dim (build-time python: {:.4})", engine.info.bpd);
            args.finish().map_err(|e| anyhow!(e))
        }
        "sample" => {
            let man = manifest()?;
            let model = args.get("model", "mnist_bin");
            let method = Method::parse(&args.get("method", "fpi"), args.num::<usize>("t-use", 1))
                .ok_or_else(|| anyhow!("unknown method"))?;
            let batch = args.num::<usize>("batch", 1);
            let seed = args.num::<u64>("seed", 0);
            let engine = Engine::load(&man, &model)?;
            let res = engine.sample_batch(method, batch, seed)?;
            println!(
                "{model} {} b{batch} seed {seed}: {} ARM calls ({:.1}% of d={}), {}",
                method.label(),
                res.arm_calls,
                res.calls_pct(engine.info.dim),
                engine.info.dim,
                fmt_duration(res.wall_secs)
            );
            if let Some(path) = args.opt("ppm") {
                let info = &engine.info;
                let tiles: Vec<_> = res
                    .jobs
                    .iter()
                    .map(|j| predsamp::sampler::trace::render_with_mistakes(j, info.width, info.height, info.channels, info.categories).upscale(4))
                    .collect();
                predsamp::substrate::image::Image::grid(&tiles, 4).write_ppm(&path)?;
                println!("wrote {path}");
            }
            args.finish().map_err(|e| anyhow!(e))
        }
        "serve" => {
            let d = ServeConfig::default();
            // `worker_threads` used to be accepted (and silently ignored by
            // the single-threaded edge); now that the connection plane really
            // is multi-threaded the knob has an honest name.
            if args.flag("worker-threads") {
                bail!("--worker-threads was retired: the edge is a sharded event loop now; use --conn-threads N (connection shards) and --engine-threads N (engine workers)");
            }
            let readiness_name = args.get("readiness", d.readiness.label());
            let readiness =
                ReadinessKind::parse(&readiness_name).ok_or_else(|| anyhow!("unknown --readiness {readiness_name:?} (auto|scan|epoll)"))?;
            let policy_name = args.get("policy", d.policy.label());
            let policy = PolicyKind::parse(&policy_name).ok_or_else(|| anyhow!("unknown --policy {policy_name:?} (occupancy|latency|slo)"))?;
            let admission = match args.opt("absorb-budget") {
                Some(n) => AdmissionKind::Budget(n.parse().map_err(|_| anyhow!("--absorb-budget must be a job count"))?),
                None => AdmissionKind::OldestFirst,
            };
            // Placement: `--pin` implies pinned, `--max-engines` implies
            // capped, and `--placement pinned` alone activates the
            // manifest's own `"pin"` fields.
            let pins = args
                .all("pin")
                .iter()
                .map(|p| predsamp::coordinator::placement::parse_pin(p))
                .collect::<Result<Vec<_>>>()?;
            let max_engines = match args.opt("max-engines") {
                Some(n) => Some(n.parse::<usize>().map_err(|_| anyhow!("--max-engines must be an engine count"))?),
                None => None,
            };
            if !pins.is_empty() && max_engines.is_some() {
                bail!("--pin and --max-engines select different placement policies");
            }
            let placement_name = args.get("placement", "");
            let placement = match placement_name.as_str() {
                "" => match (pins.is_empty(), max_engines) {
                    (_, Some(cap)) => PlacementKind::CapacityCapped(cap),
                    (false, None) => PlacementKind::Pinned(pins.clone()),
                    (true, None) => PlacementKind::ReplicateAll,
                },
                "replicate" => {
                    ensure!(pins.is_empty() && max_engines.is_none(), "--placement replicate conflicts with --pin/--max-engines");
                    PlacementKind::ReplicateAll
                }
                "pinned" => {
                    ensure!(max_engines.is_none(), "--placement pinned conflicts with --max-engines");
                    PlacementKind::Pinned(pins.clone())
                }
                "capped" => PlacementKind::CapacityCapped(max_engines.ok_or_else(|| anyhow!("--placement capped needs --max-engines N"))?),
                other => bail!("unknown --placement {other:?} (replicate|pinned|capped)"),
            };
            let cfg = ServeConfig {
                addr: args.get("addr", &d.addr),
                max_batch: args.num::<usize>("max-batch", d.max_batch),
                max_wait: std::time::Duration::from_millis(args.num::<u64>("max-wait-ms", 20)),
                continuous: !args.flag("sync"),
                elastic: !args.flag("no-elastic"),
                steal: !args.flag("no-steal"),
                conn_threads: args.num::<usize>("conn-threads", d.conn_threads),
                readiness,
                engine_threads: args.num::<usize>("engine-threads", d.engine_threads),
                policy,
                slo: std::time::Duration::from_millis(args.num::<u64>("slo-ms", d.slo.as_millis() as u64)),
                admission,
                placement,
                reply_timeout: std::time::Duration::from_millis(args.num::<u64>("reply-timeout-ms", d.reply_timeout.as_millis() as u64)),
                max_line_len: args.num::<usize>("max-line-len", d.max_line_len),
                outbound_cap: args.num::<usize>("outbound-cap", d.outbound_cap),
                rate_limit: args.num::<u32>("rate-limit", d.rate_limit),
                max_conns: args.num::<usize>("max-conns", d.max_conns),
                streaming: !args.flag("no-stream"),
                framing: !args.flag("no-frame"),
                variants: !args.flag("no-variants"),
            };
            args.finish().map_err(|e| anyhow!(e))?;
            let (engine_threads, batching) = (cfg.engine_threads, if cfg.continuous { "continuous" } else { "sync" });
            let policy_label = cfg.policy.label();
            let placement_label = cfg.placement.label();
            // No compiled artifacts: serve the pure-rust mock demo pair
            // instead of refusing to start (same fallback as the demo),
            // so the quickstart works on a clean checkout.
            let dir = predsamp::artifacts_dir();
            let dir = if dir.join("manifest.json").exists() {
                dir
            } else {
                let tmp = std::env::temp_dir().join(format!("predsamp-serve-mock-{}", std::process::id()));
                predsamp::runtime::artifact::write_mock_manifest(&tmp, &predsamp::runtime::artifact::MockModelSpec::demo_pair())?;
                println!("no compiled artifacts found — serving the pure-rust mock ARM demo pair (mock_a, mock_b)");
                tmp
            };
            let handle = server::spawn(dir, cfg)?;
            println!(
                "predsamp serving on {} ({engine_threads} engine workers, {batching} batching, {policy_label} sizing, {placement_label} placement; ctrl-c to stop)",
                handle.addr
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "route" => {
            let d = RouterConfig::default();
            let readiness_name = args.get("readiness", d.readiness.label());
            let readiness =
                ReadinessKind::parse(&readiness_name).ok_or_else(|| anyhow!("unknown --readiness {readiness_name:?} (auto|scan|epoll)"))?;
            let backends = args.all("backend");
            ensure!(!backends.is_empty(), "route needs at least one --backend host:port");
            // Fleet placement mirrors the serve arm's dispatch:
            // `--fleet-pin` implies pinned, `--fleet-max-backends` implies
            // capped, `--fleet-placement` spells it out explicitly.
            let pins = args
                .all("fleet-pin")
                .iter()
                .map(|p| predsamp::coordinator::placement::parse_pin(p))
                .collect::<Result<Vec<_>>>()?;
            let max_backends = match args.opt("fleet-max-backends") {
                Some(n) => Some(n.parse::<usize>().map_err(|_| anyhow!("--fleet-max-backends must be a namespace budget"))?),
                None => None,
            };
            if !pins.is_empty() && max_backends.is_some() {
                bail!("--fleet-pin and --fleet-max-backends select different fleet placements");
            }
            let placement_name = args.get("fleet-placement", "");
            let fleet_placement = match placement_name.as_str() {
                "" => match (pins.is_empty(), max_backends) {
                    (_, Some(cap)) => PlacementKind::CapacityCapped(cap),
                    (false, None) => PlacementKind::Pinned(pins.clone()),
                    (true, None) => PlacementKind::ReplicateAll,
                },
                "replicate" => {
                    ensure!(pins.is_empty() && max_backends.is_none(), "--fleet-placement replicate conflicts with --fleet-pin/--fleet-max-backends");
                    PlacementKind::ReplicateAll
                }
                "pinned" => {
                    ensure!(max_backends.is_none(), "--fleet-placement pinned conflicts with --fleet-max-backends");
                    PlacementKind::Pinned(pins.clone())
                }
                "capped" => PlacementKind::CapacityCapped(max_backends.ok_or_else(|| anyhow!("--fleet-placement capped needs --fleet-max-backends N"))?),
                other => bail!("unknown --fleet-placement {other:?} (replicate|pinned|capped)"),
            };
            let cfg = RouterConfig {
                addr: args.get("addr", &d.addr),
                backends,
                fleet_placement,
                probe_interval: std::time::Duration::from_millis(args.num::<u64>("probe-interval-ms", d.probe_interval.as_millis() as u64)),
                probe_timeout: std::time::Duration::from_millis(args.num::<u64>("probe-timeout-ms", d.probe_timeout.as_millis() as u64)),
                probe_fails: args.num::<u32>("probe-fails", d.probe_fails),
                max_hops: args.num::<u32>("max-hops", d.max_hops),
                conn_threads: args.num::<usize>("conn-threads", d.conn_threads),
                readiness,
                max_line_len: args.num::<usize>("max-line-len", d.max_line_len),
                outbound_cap: args.num::<usize>("outbound-cap", d.outbound_cap),
                rate_limit: args.num::<u32>("rate-limit", d.rate_limit),
                max_conns: args.num::<usize>("max-conns", d.max_conns),
                reply_timeout: std::time::Duration::from_millis(args.num::<u64>("reply-timeout-ms", d.reply_timeout.as_millis() as u64)),
            };
            args.finish().map_err(|e| anyhow!(e))?;
            let (n_backends, placement_label) = (cfg.backends.len(), cfg.fleet_placement.label());
            let handle = federation::spawn_router(cfg)?;
            println!(
                "predsamp routing on {} ({n_backends} backends, {placement_label} fleet placement; ctrl-c to stop)",
                handle.addr
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "client" => {
            let addr: std::net::SocketAddr = args.get("addr", "127.0.0.1:7199").parse()?;
            let json = args.opt("json").ok_or_else(|| anyhow!("--json required"))?;
            let stream = args.flag("stream");
            args.finish().map_err(|e| anyhow!(e))?;
            let mut c = server::Client::connect(&addr)?;
            if stream {
                // Print each streamed per-job event as it lands, then the
                // closing response.
                let fin = c.call_streamed(&json, &mut |ev| println!("{ev}"))?;
                println!("{fin}");
            } else {
                println!("{}", c.call(&json)?);
            }
            Ok(())
        }
        "table1" | "table2" | "table3" => {
            let man = manifest()?;
            let seeds = seeds_of(args);
            let batches: Vec<usize> = {
                let l = args.list("batches");
                if l.is_empty() { vec![1, 32] } else { l.iter().filter_map(|s| s.parse().ok()).collect() }
            };
            let models = args.list("models");
            args.finish().map_err(|e| anyhow!(e))?;
            match cmd {
                "table1" => tables::table1(&man, &seeds, &batches, &models)?,
                "table2" => tables::table2(&man, &seeds, &batches, &models)?,
                _ => tables::table3(&man, &seeds)?,
            };
            Ok(())
        }
        "fig3" | "fig4" | "fig5" | "fig6" => {
            let man = manifest()?;
            let seed = args.num::<u64>("seed", 10); // the paper's figure seed
            let out = std::path::PathBuf::from(args.get("out", "results"));
            args.finish().map_err(|e| anyhow!(e))?;
            let written = match cmd {
                "fig3" => figures::fig_samples(&man, "mnist_bin", &out, seed, 20)?,
                "fig4" => figures::fig_samples(&man, "cifar5", &out, seed, 1)?,
                "fig5" => figures::fig5(&man, "latent_cifar", &out, seed)?,
                _ => figures::fig6(&man, "latent_cifar", &out, seed)?,
            };
            for w in written {
                println!("wrote {w}");
            }
            Ok(())
        }
        "verify" => {
            // Release gate: the exactness guarantee across every model and
            // method, through the compiled artifacts.
            let man = manifest()?;
            let seed = args.num::<u64>("seed", 0);
            args.finish().map_err(|e| anyhow!(e))?;
            let mut checked = 0;
            for name in man.models.keys().cloned().collect::<Vec<_>>() {
                let engine = Engine::load(&man, &name)?;
                let Some(&b) = engine.batch_sizes().first() else { continue };
                let base = engine.sample_batch(Method::Baseline, b, seed)?;
                for method in [
                    Method::Zeros,
                    Method::PredictLast,
                    Method::Fpi,
                    Method::Forecast { t_use: 1 },
                ] {
                    let res = engine.sample_batch(method, b, seed)?;
                    for (j, job) in res.jobs.iter().enumerate() {
                        if job.x != base.jobs[j].x {
                            bail!("{name}/{}: slot {j} diverged from ancestral", method.label());
                        }
                    }
                    checked += 1;
                    println!("  ✓ {name:<16} {:<16} b{b}: exact ({} calls vs {})", method.label(), res.arm_calls, base.arm_calls);
                }
            }
            println!("verify: {checked} (model, method) pairs exact");
            Ok(())
        }
        "figs-appendix" => {
            // Appendix C (Figs. 7-13): the same sample/mistake galleries
            // for every remaining model.
            let man = manifest()?;
            let seed = args.num::<u64>("seed", 10);
            let out = std::path::PathBuf::from(args.get("out", "results"));
            args.finish().map_err(|e| anyhow!(e))?;
            for (model, t) in [("svhn8", 1usize), ("cifar8", 1)] {
                for w in figures::fig_samples(&man, model, &out, seed, t)? {
                    println!("wrote {w}");
                }
            }
            for model in ["latent_svhn", "latent_in32"] {
                for w in figures::fig5(&man, model, &out, seed)? {
                    println!("wrote {w}");
                }
            }
            Ok(())
        }
        "schedule-ablation" => {
            let man = manifest()?;
            let model = args.get("model", "latent_cifar");
            let jobs = args.num::<usize>("jobs", 64);
            let seed = args.num::<u64>("seed", 0);
            args.finish().map_err(|e| anyhow!(e))?;
            let engine = Engine::load(&man, &model)?;
            let bs = *engine.batch_sizes().last().unwrap();
            let exe = engine.exe_for(bs, false)?;
            let cont = scheduler::run_continuous(exe, Box::new(forecast::FpiReuse), jobs, seed)?;
            let sync = scheduler::run_sync_chunks(exe, Box::new(forecast::FpiReuse), jobs, seed)?;
            println!("scheduler ablation: {model}, {jobs} jobs, batch {bs} (FPI)");
            for (tag, r) in [("continuous", &cont), ("sync", &sync)] {
                println!(
                    "  {tag:<11} passes {:>5}  calls/job {:>7.1}  occupancy {:>5.1}%  wall {}  jobs/s {:.2}",
                    r.total_passes,
                    r.calls_per_job,
                    100.0 * r.occupancy,
                    fmt_duration(r.wall_secs),
                    jobs as f64 / r.wall_secs
                );
            }
            for i in 0..jobs {
                assert_eq!(cont.results[i].x, sync.results[i].x, "job {i} sample must not depend on scheduling");
            }
            println!("  ✓ all {jobs} samples identical under both schedulers");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
