#!/usr/bin/env python3
"""Bench-regression check: diff fresh bench JSON against checked-in baselines.

Usage:
    python3 scripts/bench_regression.py [--fresh-dir DIR] [--baseline-dir DIR]
                                        [--proposed-dir DIR]

For each benchmark result (BENCH_sampler_hotpath.json,
BENCH_serving_load.json), freshly written by the bench steps:

* Baseline missing, or a ``{"bootstrap": true}`` placeholder -> print a
  notice and pass. The fresh JSON is staged under the proposed dir either
  way (CI uploads it as the ``bench-baselines-proposed`` artifact);
  committing a proposed file over the placeholder blesses it as the real
  baseline.
* Real baseline -> the fresh result must be a *structural superset*: every
  key path present in the baseline must exist in the fresh run, with the
  same JSON type. A scenario or gauge that silently vanished fails the
  job. Every shared numeric leaf is printed as a delta table; wall-clock
  and throughput numbers are informational only (CI machines are far too
  noisy to gate on time) -- the hard perf gates live *inside* the benches
  as structural assertions (pinning loads < replicate loads, epoll
  ready/tick < scan ready/tick, streamed TTFS < group-close).
"""

import argparse
import json
import os
import shutil
import sys

BENCHES = ["BENCH_sampler_hotpath.json", "BENCH_serving_load.json"]


def flatten(value, prefix=""):
    """Yield (path, leaf) pairs; lists index by position."""
    if isinstance(value, dict):
        for key in sorted(value):
            yield from flatten(value[key], f"{prefix}.{key}" if prefix else key)
    elif isinstance(value, list):
        for i, item in enumerate(value):
            yield from flatten(item, f"{prefix}[{i}]")
    else:
        yield prefix, value


def json_type(leaf):
    if isinstance(leaf, bool):
        return "bool"
    if isinstance(leaf, (int, float)):
        return "number"
    if leaf is None:
        return "null"
    return "string"


def compare(name, baseline, fresh):
    """Return the number of structural regressions, printing as it goes."""
    base_leaves = dict(flatten(baseline))
    fresh_leaves = dict(flatten(fresh))
    regressions = 0
    for path, base_leaf in base_leaves.items():
        if path not in fresh_leaves:
            print(f"  REGRESSION {name}: baseline path {path!r} missing from the fresh run")
            regressions += 1
        elif json_type(base_leaf) != json_type(fresh_leaves[path]):
            print(
                f"  REGRESSION {name}: {path!r} changed type "
                f"{json_type(base_leaf)} -> {json_type(fresh_leaves[path])}"
            )
            regressions += 1
    shown = 0
    for path, base_leaf in base_leaves.items():
        fresh_leaf = fresh_leaves.get(path)
        if isinstance(base_leaf, (int, float)) and not isinstance(base_leaf, bool) and isinstance(fresh_leaf, (int, float)):
            delta = fresh_leaf - base_leaf
            pct = f"{100.0 * delta / base_leaf:+.1f}%" if base_leaf else "n/a"
            print(f"  {name}: {path:<60} {base_leaf:>14.6g} -> {fresh_leaf:>14.6g}  ({pct})")
            shown += 1
    if not shown:
        print(f"  {name}: no shared numeric leaves to diff")
    return regressions


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh-dir", default=".", help="where the bench steps wrote their JSON")
    ap.add_argument("--baseline-dir", default="benches/baselines", help="checked-in baselines")
    ap.add_argument("--proposed-dir", default="bench-baselines-proposed", help="staging dir for fresh results")
    args = ap.parse_args()

    os.makedirs(args.proposed_dir, exist_ok=True)
    failures = 0
    for bench in BENCHES:
        fresh_path = os.path.join(args.fresh_dir, bench)
        baseline_path = os.path.join(args.baseline_dir, bench)
        if not os.path.exists(fresh_path):
            print(f"  REGRESSION {bench}: fresh result was never written (bench step failed?)")
            failures += 1
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        shutil.copy(fresh_path, os.path.join(args.proposed_dir, bench))
        if not os.path.exists(baseline_path):
            print(f"  NOTICE {bench}: no baseline at {baseline_path}; staged the fresh run as a proposed baseline")
            continue
        with open(baseline_path) as f:
            baseline = json.load(f)
        if baseline.get("bootstrap") is True:
            print(f"  NOTICE {bench}: baseline is a bootstrap placeholder; commit the proposed file to bless it")
            continue
        failures += compare(bench, baseline, fresh)
    if failures:
        print(f"bench regression check: {failures} structural regression(s)")
        return 1
    print(f"bench regression check: ok ({len(BENCHES)} benches; proposed baselines staged in {args.proposed_dir}/)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
