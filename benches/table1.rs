//! Regenerates the paper's Table 1 (explicit likelihood modeling):
//! ARM calls %, wall time, and speedup for baseline / forecast-zeros /
//! predict-last / FPI / FPI+forecasting, at batch sizes 1 and 32.
//!
//!     cargo bench --bench table1 [-- --seeds 10 --batches 1,32 --models mnist_bin,cifar5]
//!
//! Default is 3 seeds (the paper uses 10; this substrate has one CPU core
//! — pass --seeds 10 for the full protocol).

use predsamp::bench::tables;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let seeds: Vec<u64> = (0..args.num::<usize>("seeds", 2) as u64).collect();
    let batches: Vec<usize> = {
        let l = args.list("batches");
        if l.is_empty() { vec![1, 32] } else { l.iter().filter_map(|s| s.parse().ok()).collect() }
    };
    let models = args.list("models");
    let man = Manifest::load(predsamp::artifacts_dir())?;
    let rows = tables::table1(&man, &seeds, &batches, &models)?;

    // Shape checks mirroring the paper's qualitative claims.
    let pct = |model: &str, method: &str, b: usize| {
        rows.iter()
            .find(|r| r.model == model && r.method == method && r.batch == b)
            .map(|r| r.calls_pct.mean)
    };
    for b in &batches {
        if let (Some(base), Some(fpi)) = (pct("mnist_bin", "baseline", *b), pct("mnist_bin", "fpi", *b)) {
            assert!(fpi < 0.5 * base, "FPI should dominate the baseline (b{b}: {fpi:.1}% vs {base:.1}%)");
        }
    }
    println!("\ntable1 done ({} rows)", rows.len());
    Ok(())
}
