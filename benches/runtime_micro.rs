//! Runtime micro-benchmarks: per-pass latency of every model's step
//! executable at each batch size, plus the Pallas-lowered artifact parity
//! check. These are the denominators behind the table
//! timings — and the numbers the §Perf optimization pass tracks.
//!
//!     cargo bench --bench runtime_micro

use predsamp::bench::harness::bench;
use predsamp::runtime::artifact::Manifest;
use predsamp::runtime::step::StepExecutable;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load(predsamp::artifacts_dir())?;
    println!("step-executable latency per parallel inference pass:");
    for (name, info) in &man.models {
        for b in info.step_batch_sizes() {
            let exe = StepExecutable::load(man.path(info.file(&format!("step_b{b}"))?), info, b)?;
            let x = vec![0i32; b * info.dim];
            let mut out = predsamp::runtime::step::StepOutput::default();
            let r = bench(&format!("{name} b{b} (logp+fore)"), 2, 10, || {
                exe.run_into(&x, &mut out).unwrap();
            });
            println!("  {}", r.report());
            // The logp-only variant (perf optimization #1, EXPERIMENTS §Perf).
            if let Ok(lp) = info.file(&format!("steplp_b{b}")) {
                let exe = StepExecutable::load_variant(man.path(lp), info, b, false)?;
                let r2 = bench(&format!("{name} b{b} (logp only)"), 2, 10, || {
                    exe.run_into(&x, &mut out).unwrap();
                });
                println!("  {}  ({:.2}x vs full)", r2.report(), r.secs.mean / r2.secs.mean);
            }
        }
    }

    // Pallas-path artifact: parity + latency vs the reference lowering.
    let info = man.model("mnist_bin")?;
    if let Ok(pfile) = info.file("step_pallas_b1") {
        let pexe = StepExecutable::load(man.path(pfile), info, 1)?;
        let rexe = StepExecutable::load(man.path(info.file("step_b1")?), info, 1)?;
        let x: Vec<i32> = (0..info.dim as i32).map(|i| i % 2).collect();
        let po = pexe.run(&x)?;
        let ro = rexe.run(&x)?;
        let max_err = po
            .logp
            .iter()
            .zip(&ro.logp)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("\npallas-lowered artifact vs reference lowering: max |Δlogp| = {max_err:.2e}");
        assert!(max_err < 1e-3, "pallas artifact must match reference numerics");
        let mut out = predsamp::runtime::step::StepOutput::default();
        let rp = bench("mnist_bin pallas b1", 1, 5, || {
            pexe.run_into(&x, &mut out).unwrap();
        });
        println!("  {}", rp.report());
    }
    Ok(())
}
