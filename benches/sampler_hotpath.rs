//! Sampler hot-path benchmark: full-shape passes vs frontier-aware
//! [`PassPlan`] passes (+ batch down-shifting) on the mock serving mix,
//! plus a deep-queue **elastic** scenario (live arrivals, up-shifting)
//! against the down-shift-only scheduler.
//!
//! The paper's speedup is measured in ARM inference *calls*; this bench
//! measures what each call costs. A full pass always evaluates
//! `B * (d + P*T)` output rows — log-probs for converged slots and
//! finalized prefixes, forecast heads nobody reads; a planned pass
//! evaluates only the live spans (plus heads only when the policy
//! consumes them). Both schedules are run over the same job queues and
//! asserted bitwise identical, then the positions-evaluated-per-job
//! reduction and wall time are reported and written to
//! `BENCH_sampler_hotpath.json` (machine-readable, uploaded as a CI
//! artifact) to seed the perf trajectory.
//!
//! The elastic scenario trickles awkwardly-sized bursts into a running
//! schedule. The down-shift-only baseline (PR 2's scheduler) must run
//! each accumulation of arrivals as its own schedule — paying priming
//! waste and a straggler drain tail per schedule — while the elastic
//! scheduler absorbs arrivals into converged slots mid-flight, so its
//! aggregate `calls_per_job` must come out strictly lower (asserted, and
//! both are bitwise identical to the batch-1 reference).
//!
//! The **compiled variants** scenario prices the shape-variant catalog:
//! the same job mix through the pre-catalog compiled serving path (one
//! fixed b=8 export paying `8 * (d + P*T)` per pass no matter what the
//! plan allows) and through a [`VariantCatalog`] carrying the AOT
//! exporter's span ladder (d/8, d/4, d/2 plus the full-shape anchors)
//! at batches `{1, 2, 4, 8}`. Unlike the plan rows above, the catalog
//! pays quantized *device* shapes — the cheapest exported variant
//! covering the plan — so its gate (>= 2x fewer evaluated positions at
//! bitwise-identical samples) is the compiled-backend win net of shape
//! quantization.
//!
//! The **sparse-family policy** scenario runs 3-job groups on a `{1, 4}`
//! export family under each sizing policy
//! ([`predsamp::coordinator::policy`]): occupancy-first serializes the
//! odd-sized group on full b=1 batches (best ARM-call rate, worst
//! latency), latency-lean seats everyone on b=4 (worst rate, best
//! latency), and the SLO hybrid is asserted to beat occupancy-first on
//! p50 latency without exceeding latency-lean's `calls_per_job` — while
//! a loose target recovers occupancy economics. Samples are asserted
//! bitwise identical across all policies.
//!
//!     cargo bench --bench sampler_hotpath [-- --jobs 32 --out BENCH_sampler_hotpath.json]
//!
//! [`PassPlan`]: predsamp::sampler::PassPlan
//! [`VariantCatalog`]: predsamp::runtime::step::VariantCatalog

use predsamp::coordinator::policy::{LatencyLean, OccupancyFirst, SizingPolicy, SloHybrid, SloTarget};
use predsamp::coordinator::scheduler::{self, LiveJob, ScheduleReport};
use predsamp::runtime::step::{CatalogStats, StepOutput, VariantCatalog};
use predsamp::sampler::forecast;
use predsamp::sampler::mock::MockArm;
use predsamp::sampler::noise::JobNoise;
use predsamp::sampler::{JobResult, PassPlan, StepModel};
use predsamp::substrate::cli::Args;
use predsamp::substrate::json::Value;
use predsamp::substrate::stats::percentile;
use predsamp::substrate::timer::fmt_duration;
use std::collections::VecDeque;

/// The serving mix: the two demo mock models under the methods the
/// serving bench drives (see `benches/serving_load.rs`).
const MIX: [(&str, &str); 4] = [("mock_a", "fpi"), ("mock_b", "fpi"), ("mock_a", "zeros"), ("mock_b", "learned")];

/// Groups for the deep-queue elastic scenario.
const ELASTIC_MIX: [(&str, &str); 2] = [("mock_a", "fpi"), ("mock_b", "learned")];

fn model(name: &str, batch: usize) -> MockArm {
    match name {
        // The demo pair's channel/category structure at serving-scale
        // dims (d = 192 / 256), big enough that planned passes cross the
        // shared-pool row-parallel threshold in MockArm::run_plan.
        "mock_a" => MockArm::new(batch, 3, 64, 8, 2, 2.0, 31),
        "mock_b" => MockArm::new(batch, 1, 256, 4, 2, 1.5, 17),
        other => panic!("unknown mix model {other}"),
    }
}

fn run_group(name: &str, method: &str, jobs: usize, seed: u64, plan: bool) -> anyhow::Result<ScheduleReport> {
    let family: Vec<MockArm> = if plan {
        vec![model(name, 1), model(name, 2), model(name, 4), model(name, 8)]
    } else {
        // The pre-plan hot path: one fixed-size executable, full passes.
        vec![model(name, 8)]
    };
    let refs: Vec<&MockArm> = family.iter().collect();
    let d = refs[0].dim();
    let k = refs[0].categories();
    let noises: Vec<JobNoise> = (0..jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    let fc = forecast::by_name(method, 2).expect("known method");
    scheduler::run_continuous_family_mode(&refs, fc, noises, plan)
}

/// One batch-size *view* of a shape-variant catalog — what the engine's
/// catalog-serving backend exposes per exported batch, reproduced over
/// mock span backends so the bench runs without compiled artifacts.
struct CatalogView<'a> {
    cat: &'a VariantCatalog,
    batch: usize,
}

impl StepModel for CatalogView<'_> {
    fn batch(&self) -> usize {
        self.batch
    }
    fn dim(&self) -> usize {
        self.cat.dim
    }
    fn categories(&self) -> usize {
        self.cat.categories
    }
    fn pixels(&self) -> usize {
        self.cat.pixels
    }
    fn t_fore(&self) -> usize {
        self.cat.t_fore
    }
    fn run_into(&self, x: &[i32], out: &mut StepOutput) -> anyhow::Result<()> {
        self.cat.run_full(self.batch, true, x, out).map(|_| ())
    }
    fn run_plan(&self, x: &[i32], out: &mut StepOutput, plan: &PassPlan) -> anyhow::Result<usize> {
        self.cat.run_plan(self.batch, true, x, out, plan)
    }
    fn exploits_plan(&self) -> bool {
        true
    }
}

/// Run one (model, method) group through a span-ladder catalog: the
/// exporter's ladder (d/8, d/4, d/2) plus the full-shape anchors, both
/// fore flavors, at batches `{1, 2, 4, 8}`. Every pass pays the device
/// cost of the variant the catalog selected, not the plan's exact row
/// count — the same accounting the compiled backend reports.
fn run_catalog_group(name: &str, method: &str, jobs: usize, seed: u64) -> anyhow::Result<(ScheduleReport, CatalogStats)> {
    let probe = model(name, 1);
    let (d, k) = (probe.dim(), probe.categories());
    let mut cat = VariantCatalog::new(name, d, k, probe.pixels(), probe.t_fore());
    for b in [1usize, 2, 4, 8] {
        for s in [d / 8, d / 4, d / 2, d] {
            cat.push_backend(b, s, true, Box::new(model(name, b)))?;
            cat.push_backend(b, s, false, Box::new(model(name, b)))?;
        }
    }
    cat.validate()?;
    let views: Vec<CatalogView> = [1usize, 2, 4, 8].iter().map(|&b| CatalogView { cat: &cat, batch: b }).collect();
    let refs: Vec<&CatalogView> = views.iter().collect();
    let noises: Vec<JobNoise> = (0..jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    let fc = forecast::by_name(method, 2).expect("known method");
    let rep = scheduler::run_continuous_family_mode(&refs, fc, noises, true)?;
    let stats = cat.stats();
    Ok((rep, stats))
}

/// One elastic-vs-baseline comparison (see [`run_elastic_scenario`]).
struct ElasticOutcome {
    elastic: ScheduleReport,
    results: Vec<Option<JobResult>>,
    /// Down-shift-only aggregate calls_per_job over the same arrivals.
    base_cpj: f64,
    /// Down-shift-only total ARM passes (wall-clock proxy).
    base_passes: usize,
    /// Schedules the down-shift-only baseline needed.
    base_schedules: usize,
}

/// Deep-queue elastic scenario for one (model, method) group: `jobs` jobs
/// arrive in bursts of `burst` every `gap` passes, once into a single
/// live elastic schedule and once through the down-shift-only scheduler
/// (separate schedules per accumulation of arrivals).
fn run_elastic_scenario(name: &str, method: &str, jobs: usize, burst: usize, gap: usize, seed: u64) -> anyhow::Result<ElasticOutcome> {
    let family: Vec<MockArm> = vec![model(name, 1), model(name, 2), model(name, 4), model(name, 8)];
    let refs: Vec<&MockArm> = family.iter().collect();
    let d = refs[0].dim();
    let k = refs[0].categories();
    let job = |id: usize| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) };

    // Elastic: one live schedule absorbing every burst mid-flight.
    let mut bursts: Vec<(usize, Vec<LiveJob>)> = Vec::new();
    let mut at = gap;
    let mut next = burst.min(jobs);
    while next < jobs {
        let hi = (next + burst).min(jobs);
        bursts.push((at, (next..hi).map(job).collect()));
        next = hi;
        at += gap;
    }
    let arrival_ticks: Vec<(usize, usize)> = bursts.iter().map(|(at, b)| (*at, b.len())).collect();
    let mut feed = scheduler::TickBurstFeed::new(jobs, bursts);
    let initial: Vec<LiveJob> = (0..burst.min(jobs)).map(job).collect();
    let fc = forecast::by_name(method, 2).expect("known method");
    let elastic = scheduler::run_elastic_family(&refs, fc, initial, &mut feed)?;

    // Down-shift-only baseline: arrivals cannot join a running schedule,
    // so each accumulation of bursts runs as its own schedule (PR 2's
    // serving behavior — the next window executes whatever queued while
    // the previous schedule ran). The pass clock links the two.
    let mut pending: VecDeque<(usize, (usize, usize))> = arrival_ticks
        .iter()
        .scan(burst.min(jobs), |lo, (at, len)| {
            let span = (*lo, *lo + len);
            *lo += len;
            Some((*at, span))
        })
        .collect();
    pending.push_front((0, (0, burst.min(jobs))));
    let mut clock = 0usize;
    let mut slot_passes = 0f64;
    let mut base_passes = 0usize;
    let mut schedules = 0usize;
    let mut base_results: Vec<Option<JobResult>> = (0..jobs).map(|_| None).collect();
    while let Some(&(at, _)) = pending.front() {
        // Everything arrived by `clock` forms the next schedule; if the
        // queue is idle, jump to the next arrival (idle time costs no
        // slot-passes).
        if at > clock {
            clock = at;
        }
        let mut ids: Vec<usize> = Vec::new();
        while pending.front().is_some_and(|(a, _)| *a <= clock) {
            let (_, (lo, hi)) = pending.pop_front().expect("non-empty");
            ids.extend(lo..hi);
        }
        let noises: Vec<JobNoise> = ids.iter().map(|&id| JobNoise::new(seed, id as u64, d, k)).collect();
        let fc = forecast::by_name(method, 2).expect("known method");
        let rep = scheduler::run_continuous_family(&refs, fc, noises)?;
        slot_passes += rep.calls_per_job * ids.len() as f64;
        base_passes += rep.total_passes;
        clock += rep.total_passes;
        schedules += 1;
        for (i, id) in ids.into_iter().enumerate() {
            base_results[id] = Some(rep.results[i].clone());
        }
    }
    let base_cpj = slot_passes / jobs as f64;

    // Elasticity must be exact: both schedules bitwise agree per job id.
    for id in 0..jobs {
        let e = feed.results[id].as_ref().expect("elastic job completed");
        let b = base_results[id].as_ref().expect("baseline job completed");
        assert_eq!(e.x, b.x, "{name}/{method} job {id}: elasticity changed the sample");
    }
    Ok(ElasticOutcome { elastic, results: feed.results, base_cpj, base_passes, base_schedules: schedules })
}

/// One policy's outcome on a sparse-family group (see
/// [`run_policy_group`]): per-job latency in passes, the schedule
/// report, and the samples (for the cross-policy exactness assert).
struct PolicyOutcome {
    rep: ScheduleReport,
    latency_passes: Vec<usize>,
    samples: Vec<Vec<i32>>,
}

/// Run one 3-job group on a sparse `{1, 4}` export family under
/// `sizing` — the ROADMAP's pathological shape: 3 jobs cannot fill the
/// b=4 export, so occupancy-first sizing runs them one at a time on
/// full b=1 batches (optimal ARM-call rate, serialized latency) while
/// latency-lean seats all three on b=4 at once (dead slot, minimal
/// latency) and the SLO hybrid up-shifts exactly when the projected
/// queue delay blows its target. Latency is measured deterministically
/// in ARM passes (arrival tick 0 → completion pass).
fn run_policy_group(name: &str, method: &str, seed: u64, sizing: &dyn SizingPolicy) -> anyhow::Result<PolicyOutcome> {
    const GROUP: usize = 3;
    let family: Vec<MockArm> = vec![model(name, 1), model(name, 4)];
    let refs: Vec<&MockArm> = family.iter().collect();
    let d = refs[0].dim();
    let k = refs[0].categories();
    let initial: Vec<LiveJob> = (0..GROUP).map(|id| LiveJob { tag: id as u64, noise: JobNoise::new(seed, id as u64, d, k) }).collect();
    let mut feed = scheduler::TickBurstFeed::new(GROUP, Vec::new());
    let fc = forecast::by_name(method, 2).expect("known method");
    let rep = scheduler::run_elastic_family_policy(&refs, fc, initial, &mut feed, sizing)?;
    let latency_passes: Vec<usize> = (0..GROUP).map(|id| feed.completed_pass[id].expect("job completed")).collect();
    let samples: Vec<Vec<i32>> = feed.results.into_iter().map(|r| r.expect("job completed").x).collect();
    Ok(PolicyOutcome { rep, latency_passes, samples })
}

fn report_value(r: &ScheduleReport, jobs: usize) -> Value {
    Value::obj(vec![
        ("positions", Value::num(r.positions_evaluated as f64)),
        ("positions_per_job", Value::num(r.positions_evaluated as f64 / jobs as f64)),
        ("passes", Value::num(r.total_passes as f64)),
        ("calls_per_job", Value::num(r.calls_per_job)),
        ("occupancy", Value::num(r.occupancy)),
        ("downshifts", Value::num(r.downshifts as f64)),
        ("upshifts", Value::num(r.upshifts as f64)),
        ("min_batch", Value::num(r.min_batch as f64)),
        ("wall_secs", Value::num(r.wall_secs)),
    ])
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let jobs = args.num::<usize>("jobs", 32);
    let out_path = args.get("out", "BENCH_sampler_hotpath.json");

    println!("sampler hotpath: {jobs} jobs/group over {} mix groups (mock ARM, B=8 full vs planned+downshift)", MIX.len());
    let mut groups = Vec::new();
    let (mut tot_full, mut tot_plan) = (0usize, 0usize);
    let (mut wall_full, mut wall_plan) = (0f64, 0f64);
    for (gi, (name, method)) in MIX.iter().enumerate() {
        let seed = 1000 + gi as u64;
        let full = run_group(name, method, jobs, seed, false)?;
        let plan = run_group(name, method, jobs, seed, true)?;
        for i in 0..jobs {
            assert_eq!(plan.results[i].x, full.results[i].x, "{name}/{method} job {i}: planned schedule changed the sample");
        }
        let d = model(name, 1).dim();
        let reduction = full.positions_evaluated as f64 / plan.positions_evaluated.max(1) as f64;
        println!(
            "  {name:>6}/{method:<7} d={d:<3} positions/job {:>8.0} -> {:>7.0}  ({reduction:.2}x less)  passes {:>3} -> {:>3}  wall {} -> {}",
            full.positions_evaluated as f64 / jobs as f64,
            plan.positions_evaluated as f64 / jobs as f64,
            full.total_passes,
            plan.total_passes,
            fmt_duration(full.wall_secs),
            fmt_duration(plan.wall_secs),
        );
        tot_full += full.positions_evaluated;
        tot_plan += plan.positions_evaluated;
        wall_full += full.wall_secs;
        wall_plan += plan.wall_secs;
        groups.push(Value::obj(vec![
            ("model", Value::str(*name)),
            ("method", Value::str(*method)),
            ("jobs", Value::num(jobs as f64)),
            ("dim", Value::num(d as f64)),
            ("full", report_value(&full, jobs)),
            ("plan", report_value(&plan, jobs)),
            ("positions_reduction", Value::num(reduction)),
        ]));
    }
    let reduction = tot_full as f64 / tot_plan.max(1) as f64;
    println!(
        "  total: positions/job {:.0} -> {:.0} ({reduction:.2}x reduction), wall {} -> {}",
        tot_full as f64 / (jobs * MIX.len()) as f64,
        tot_plan as f64 / (jobs * MIX.len()) as f64,
        fmt_duration(wall_full),
        fmt_duration(wall_plan)
    );

    // Shape-variant catalog scenario: the same groups served through a
    // span-ladder catalog vs the fixed b=8 full-shape export. The
    // catalog pays quantized device shapes (the cheapest exported
    // variant covering each plan), so this reduction is the compiled
    // backend's win net of shape quantization.
    println!("compiled variants: span-ladder catalog (d/8, d/4, d/2 + full anchors, b in {{1,2,4,8}}) vs fixed b=8 full-shape export");
    let mut variant_groups = Vec::new();
    let (mut vtot_full, mut vtot_cat) = (0usize, 0usize);
    for (gi, (name, method)) in MIX.iter().enumerate() {
        let seed = 1000 + gi as u64;
        let full = run_group(name, method, jobs, seed, false)?;
        let (cat, stats) = run_catalog_group(name, method, jobs, seed)?;
        for i in 0..jobs {
            assert_eq!(cat.results[i].x, full.results[i].x, "{name}/{method} job {i}: catalog serving changed the sample");
        }
        assert_eq!(
            stats.positions_evaluated,
            cat.positions_evaluated as u64,
            "{name}/{method}: catalog telemetry disagrees with the schedule's device-cost accounting"
        );
        let d = model(name, 1).dim();
        let reduction = full.positions_evaluated as f64 / cat.positions_evaluated.max(1) as f64;
        println!(
            "  {name:>6}/{method:<7} d={d:<3} positions/job {:>8.0} -> {:>7.0}  ({reduction:.2}x less)  variant hits {:>4}  fallbacks {:>3}",
            full.positions_evaluated as f64 / jobs as f64,
            cat.positions_evaluated as f64 / jobs as f64,
            stats.variant_hits,
            stats.full_shape_fallbacks,
        );
        vtot_full += full.positions_evaluated;
        vtot_cat += cat.positions_evaluated;
        variant_groups.push(Value::obj(vec![
            ("model", Value::str(*name)),
            ("method", Value::str(*method)),
            ("jobs", Value::num(jobs as f64)),
            ("dim", Value::num(d as f64)),
            ("full", report_value(&full, jobs)),
            ("catalog", report_value(&cat, jobs)),
            ("variant_hits", Value::num(stats.variant_hits as f64)),
            ("full_shape_fallbacks", Value::num(stats.full_shape_fallbacks as f64)),
            ("positions_reduction", Value::num(reduction)),
        ]));
    }
    let variants_reduction = vtot_full as f64 / vtot_cat.max(1) as f64;
    println!("  total: {variants_reduction:.2}x fewer evaluated positions through the catalog");

    // Deep-queue elastic scenario: awkward bursts trickling into a live
    // schedule vs the down-shift-only scheduler running one schedule per
    // accumulation of arrivals.
    let elastic_jobs = args.num::<usize>("elastic-jobs", 40);
    // Bursts of 5 every 3 passes: 5 jobs fill no export exactly, so the
    // down-shift-only baseline's first window runs 5 jobs on the b=8
    // executable — three dead slots for every pass until the first
    // convergence — and later windows pay their own straggler drains.
    // The elastic schedule sizes to the largest export it can *fill*
    // (parking the excess), so every pass runs a full batch and grows to
    // b=8 as arrivals outpace convergence at these dims.
    let (burst, gap) = (5usize, 3usize);
    println!("deep-queue elastic: {elastic_jobs} jobs/group in bursts of {burst} every {gap} passes, elastic vs down-shift-only");
    let mut elastic_groups = Vec::new();
    let mut elastic_ok = true;
    for (gi, (name, method)) in ELASTIC_MIX.iter().enumerate() {
        let out = run_elastic_scenario(name, method, elastic_jobs, burst, gap, 2000 + gi as u64)?;
        assert!(out.results.iter().all(|r| r.is_some()), "{name}/{method}: elastic schedule lost jobs");
        let gain = out.base_cpj / out.elastic.calls_per_job.max(1e-12);
        println!(
            "  {name:>6}/{method:<7} calls/job {:>6.2} -> {:>6.2}  ({gain:.2}x less)  passes {:>4} -> {:>4}  schedules {} -> 1  shifts +{}/-{}",
            out.base_cpj,
            out.elastic.calls_per_job,
            out.base_passes,
            out.elastic.total_passes,
            out.base_schedules,
            out.elastic.upshifts,
            out.elastic.downshifts,
        );
        elastic_ok &= out.elastic.calls_per_job < out.base_cpj && out.elastic.upshifts >= 1;
        elastic_groups.push(Value::obj(vec![
            ("model", Value::str(*name)),
            ("method", Value::str(*method)),
            ("jobs", Value::num(elastic_jobs as f64)),
            ("burst", Value::num(burst as f64)),
            ("gap_passes", Value::num(gap as f64)),
            ("elastic_calls_per_job", Value::num(out.elastic.calls_per_job)),
            ("downshift_only_calls_per_job", Value::num(out.base_cpj)),
            ("calls_per_job_gain", Value::num(gain)),
            ("elastic_passes", Value::num(out.elastic.total_passes as f64)),
            ("downshift_only_passes", Value::num(out.base_passes as f64)),
            ("downshift_only_schedules", Value::num(out.base_schedules as f64)),
            ("upshifts", Value::num(out.elastic.upshifts as f64)),
            ("downshifts", Value::num(out.elastic.downshifts as f64)),
            ("occupancy", Value::num(out.elastic.occupancy)),
        ]));
    }

    // Sparse-export-family policy scenario: 3-job groups on a {1, 4}
    // family, the shape that maximally separates the sizing policies.
    // Per group (mathematically guaranteed, not tuned): occupancy-first
    // serializes on b=1, so its median latency is the sum of two jobs'
    // pass counts, while latency-lean's is the median of the individual
    // pass counts — strictly smaller; and a tight SLO hybrid makes the
    // same decisions as latency-lean (every positive projected delay
    // exceeds the target), so it pays exactly fit's calls_per_job. A
    // loose SLO target reproduces occupancy-first's economics instead:
    // the same knob spans the whole trade.
    let policy_seeds = args.num::<u64>("policy-seeds", 4);
    println!("sparse-family policies: 3-job groups on a {{1,4}} export family, occupancy vs latency vs slo (latency in ARM passes)");
    let mut policy_groups = Vec::new();
    let mut policies_ok = true;
    for (gi, (name, method)) in ELASTIC_MIX.iter().enumerate() {
        let tight = SloHybrid { target: SloTarget::Passes(0.5) };
        let loose = SloHybrid { target: SloTarget::Passes(1e12) };
        let runs: Vec<(&str, &dyn SizingPolicy)> =
            vec![("occupancy", &OccupancyFirst), ("latency", &LatencyLean), ("slo", &tight), ("slo-loose", &loose)];
        // (label -> per-group median latencies, slot-passes, jobs)
        let mut medians: Vec<Vec<f64>> = vec![Vec::new(); runs.len()];
        let mut slot_passes: Vec<f64> = vec![0.0; runs.len()];
        let mut jobs_done = 0usize;
        for s in 0..policy_seeds {
            let seed = 3000 + 100 * gi as u64 + s;
            let mut outs = Vec::with_capacity(runs.len());
            for (_, sizing) in &runs {
                outs.push(run_policy_group(name, method, seed, *sizing)?);
            }
            for o in &outs[1..] {
                assert_eq!(o.samples, outs[0].samples, "{name}/{method} seed {seed}: sizing policy changed a sample");
            }
            jobs_done += outs[0].latency_passes.len();
            for (ri, o) in outs.iter().enumerate() {
                let lats: Vec<f64> = o.latency_passes.iter().map(|&l| l as f64).collect();
                medians[ri].push(percentile(&lats, 50.0));
                slot_passes[ri] += o.rep.calls_per_job * lats.len() as f64;
            }
            // Per-group gates (exact, not statistical): the SLO hybrid's
            // median latency beats occupancy-first's serialized median,
            // at no more than latency-lean's slot-pass cost.
            let (occ_med, fit_med, slo_med) = (*medians[0].last().unwrap(), *medians[1].last().unwrap(), *medians[2].last().unwrap());
            policies_ok &= slo_med < occ_med && slo_med <= fit_med + 1e-9;
        }
        let p50 = |ri: usize| percentile(&medians[ri], 50.0);
        let cpj = |ri: usize| slot_passes[ri] / jobs_done as f64;
        println!(
            "  {name:>6}/{method:<7} p50 latency (passes): occupancy {:>6.1}  latency {:>6.1}  slo {:>6.1}   calls/job: occupancy {:>6.2}  latency {:>6.2}  slo {:>6.2}  slo-loose {:>6.2}",
            p50(0),
            p50(1),
            p50(2),
            cpj(0),
            cpj(1),
            cpj(2),
            cpj(3),
        );
        policies_ok &= p50(2) < p50(0) && cpj(2) <= cpj(1) + 1e-9;
        // The loose target must recover occupancy-first's economics.
        policies_ok &= (cpj(3) - cpj(0)).abs() < 1e-9;
        let entry = |ri: usize| {
            Value::obj(vec![
                ("policy", Value::str(runs[ri].0)),
                ("p50_latency_passes", Value::num(p50(ri))),
                ("calls_per_job", Value::num(cpj(ri))),
            ])
        };
        policy_groups.push(Value::obj(vec![
            ("model", Value::str(*name)),
            ("method", Value::str(*method)),
            ("group_jobs", Value::num(3.0)),
            ("exports", Value::Arr(vec![Value::num(1.0), Value::num(4.0)])),
            ("seeds", Value::num(policy_seeds as f64)),
            ("policies", Value::Arr((0..runs.len()).map(entry).collect())),
        ]));
    }

    let doc = Value::obj(vec![
        ("bench", Value::str("sampler_hotpath")),
        ("jobs_per_group", Value::num(jobs as f64)),
        ("groups", Value::Arr(groups)),
        (
            "compiled_variants",
            Value::obj(vec![
                ("groups", Value::Arr(variant_groups)),
                ("full_positions", Value::num(vtot_full as f64)),
                ("catalog_positions", Value::num(vtot_cat as f64)),
                ("positions_reduction", Value::num(variants_reduction)),
            ]),
        ),
        ("elastic", Value::Arr(elastic_groups)),
        ("policies", Value::Arr(policy_groups)),
        (
            "total",
            Value::obj(vec![
                ("full_positions", Value::num(tot_full as f64)),
                ("plan_positions", Value::num(tot_plan as f64)),
                ("positions_reduction", Value::num(reduction)),
                ("full_wall_secs", Value::num(wall_full)),
                ("plan_wall_secs", Value::num(wall_plan)),
            ]),
        ),
    ]);
    std::fs::write(&out_path, format!("{doc}\n"))?;
    println!("wrote {out_path}");
    assert!(reduction >= 2.0, "plan-based passes must at least halve positions/job (got {reduction:.2}x)");
    assert!(
        variants_reduction >= 2.0,
        "the shape-variant catalog must at least halve evaluated positions vs the full-shape export (got {variants_reduction:.2}x)"
    );
    assert!(elastic_ok, "elastic schedule must up-shift and beat the down-shift-only scheduler's calls_per_job on every group");
    assert!(
        policies_ok,
        "the SLO policy must beat occupancy-first on p50 latency without exceeding latency-lean's calls_per_job (and a loose target must recover occupancy economics)"
    );
    Ok(())
}
