//! Sampler hot-path benchmark: full-shape passes vs frontier-aware
//! [`PassPlan`] passes (+ batch down-shifting) on the mock serving mix.
//!
//! The paper's speedup is measured in ARM inference *calls*; this bench
//! measures what each call costs. A full pass always evaluates
//! `B * (d + P*T)` output rows — log-probs for converged slots and
//! finalized prefixes, forecast heads nobody reads; a planned pass
//! evaluates only the live spans (plus heads only when the policy
//! consumes them). Both schedules are run over the same job queues and
//! asserted bitwise identical, then the positions-evaluated-per-job
//! reduction and wall time are reported and written to
//! `BENCH_sampler_hotpath.json` (machine-readable, uploaded as a CI
//! artifact) to seed the perf trajectory.
//!
//!     cargo bench --bench sampler_hotpath [-- --jobs 32 --out BENCH_sampler_hotpath.json]
//!
//! [`PassPlan`]: predsamp::sampler::PassPlan

use predsamp::coordinator::scheduler::{self, ScheduleReport};
use predsamp::sampler::forecast;
use predsamp::sampler::mock::MockArm;
use predsamp::sampler::noise::JobNoise;
use predsamp::sampler::StepModel;
use predsamp::substrate::cli::Args;
use predsamp::substrate::json::Value;
use predsamp::substrate::timer::fmt_duration;

/// The serving mix: the two demo mock models under the methods the
/// serving bench drives (see `benches/serving_load.rs`).
const MIX: [(&str, &str); 4] = [("mock_a", "fpi"), ("mock_b", "fpi"), ("mock_a", "zeros"), ("mock_b", "learned")];

fn model(name: &str, batch: usize) -> MockArm {
    match name {
        // The demo pair's channel/category structure at serving-scale
        // dims (d = 192 / 256), big enough that planned passes cross the
        // shared-pool row-parallel threshold in MockArm::run_plan.
        "mock_a" => MockArm::new(batch, 3, 64, 8, 2, 2.0, 31),
        "mock_b" => MockArm::new(batch, 1, 256, 4, 2, 1.5, 17),
        other => panic!("unknown mix model {other}"),
    }
}

fn run_group(name: &str, method: &str, jobs: usize, seed: u64, plan: bool) -> anyhow::Result<ScheduleReport> {
    let family: Vec<MockArm> = if plan {
        vec![model(name, 1), model(name, 2), model(name, 4), model(name, 8)]
    } else {
        // The pre-plan hot path: one fixed-size executable, full passes.
        vec![model(name, 8)]
    };
    let refs: Vec<&MockArm> = family.iter().collect();
    let d = refs[0].dim();
    let k = refs[0].categories();
    let noises: Vec<JobNoise> = (0..jobs).map(|id| JobNoise::new(seed, id as u64, d, k)).collect();
    let fc = forecast::by_name(method, 2).expect("known method");
    scheduler::run_continuous_family_mode(&refs, fc, noises, plan)
}

fn report_value(r: &ScheduleReport, jobs: usize) -> Value {
    Value::obj(vec![
        ("positions", Value::num(r.positions_evaluated as f64)),
        ("positions_per_job", Value::num(r.positions_evaluated as f64 / jobs as f64)),
        ("passes", Value::num(r.total_passes as f64)),
        ("calls_per_job", Value::num(r.calls_per_job)),
        ("occupancy", Value::num(r.occupancy)),
        ("downshifts", Value::num(r.downshifts as f64)),
        ("min_batch", Value::num(r.min_batch as f64)),
        ("wall_secs", Value::num(r.wall_secs)),
    ])
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let jobs = args.num::<usize>("jobs", 32);
    let out_path = args.get("out", "BENCH_sampler_hotpath.json");

    println!("sampler hotpath: {jobs} jobs/group over {} mix groups (mock ARM, B=8 full vs planned+downshift)", MIX.len());
    let mut groups = Vec::new();
    let (mut tot_full, mut tot_plan) = (0usize, 0usize);
    let (mut wall_full, mut wall_plan) = (0f64, 0f64);
    for (gi, (name, method)) in MIX.iter().enumerate() {
        let seed = 1000 + gi as u64;
        let full = run_group(name, method, jobs, seed, false)?;
        let plan = run_group(name, method, jobs, seed, true)?;
        for i in 0..jobs {
            assert_eq!(plan.results[i].x, full.results[i].x, "{name}/{method} job {i}: planned schedule changed the sample");
        }
        let d = model(name, 1).dim();
        let reduction = full.positions_evaluated as f64 / plan.positions_evaluated.max(1) as f64;
        println!(
            "  {name:>6}/{method:<7} d={d:<3} positions/job {:>8.0} -> {:>7.0}  ({reduction:.2}x less)  passes {:>3} -> {:>3}  wall {} -> {}",
            full.positions_evaluated as f64 / jobs as f64,
            plan.positions_evaluated as f64 / jobs as f64,
            full.total_passes,
            plan.total_passes,
            fmt_duration(full.wall_secs),
            fmt_duration(plan.wall_secs),
        );
        tot_full += full.positions_evaluated;
        tot_plan += plan.positions_evaluated;
        wall_full += full.wall_secs;
        wall_plan += plan.wall_secs;
        groups.push(Value::obj(vec![
            ("model", Value::str(*name)),
            ("method", Value::str(*method)),
            ("jobs", Value::num(jobs as f64)),
            ("dim", Value::num(d as f64)),
            ("full", report_value(&full, jobs)),
            ("plan", report_value(&plan, jobs)),
            ("positions_reduction", Value::num(reduction)),
        ]));
    }
    let reduction = tot_full as f64 / tot_plan.max(1) as f64;
    println!(
        "  total: positions/job {:.0} -> {:.0} ({reduction:.2}x reduction), wall {} -> {}",
        tot_full as f64 / (jobs * MIX.len()) as f64,
        tot_plan as f64 / (jobs * MIX.len()) as f64,
        fmt_duration(wall_full),
        fmt_duration(wall_plan)
    );

    let doc = Value::obj(vec![
        ("bench", Value::str("sampler_hotpath")),
        ("jobs_per_group", Value::num(jobs as f64)),
        ("groups", Value::Arr(groups)),
        (
            "total",
            Value::obj(vec![
                ("full_positions", Value::num(tot_full as f64)),
                ("plan_positions", Value::num(tot_plan as f64)),
                ("positions_reduction", Value::num(reduction)),
                ("full_wall_secs", Value::num(wall_full)),
                ("plan_wall_secs", Value::num(wall_plan)),
            ]),
        ),
    ]);
    std::fs::write(&out_path, format!("{doc}\n"))?;
    println!("wrote {out_path}");
    assert!(reduction >= 2.0, "plan-based passes must at least halve positions/job (got {reduction:.2}x)");
    Ok(())
}
