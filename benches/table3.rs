//! Regenerates the paper's Table 3 (ablations, 8-bit CIFAR, batch 32):
//! the effect of reparametrization (FPI with vs without fixed ε) and of
//! sharing the ARM representation with the forecasting modules.
//!
//!     cargo bench --bench table3 [-- --seeds 10]

use predsamp::bench::tables;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let seeds: Vec<u64> = (0..args.num::<usize>("seeds", 2) as u64).collect();
    let man = Manifest::load(predsamp::artifacts_dir())?;
    let rows = tables::table3(&man, &seeds)?;

    let pct = |method: &str| rows.iter().find(|r| r.method == method).map(|r| r.calls_pct.mean).unwrap_or(f64::NAN);
    // The paper's dominant ablation effect: removing reparametrization
    // destroys almost all of the saving (97.2% of calls in the paper).
    assert!(
        pct("fpi w/o reparam") > 2.0 * pct("fpi"),
        "reparametrization must be the dominant effect: {:.1}% vs {:.1}%",
        pct("fpi w/o reparam"),
        pct("fpi")
    );
    println!("\ntable3 done ({} rows)", rows.len());
    Ok(())
}
