//! Scheduling ablation: continuous batching (slot refill)
//! vs the paper's synchronous batch semantics, over a queue of jobs.
//! The paper predicts (§4.1) that a scheduling system "would allow
//! sampling at an average rate equal to the batch size 1 setting" — this
//! bench measures how close the refill scheduler gets.
//!
//!     cargo bench --bench scheduler_ablation [-- --model latent_cifar --jobs 64]

use predsamp::coordinator::engine::Engine;
use predsamp::coordinator::scheduler;
use predsamp::runtime::artifact::Manifest;
use predsamp::sampler::forecast::FpiReuse;
use predsamp::sampler::StepModel;
use predsamp::substrate::cli::Args;
use predsamp::substrate::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let model = args.get("model", "latent_cifar");
    let jobs = args.num::<usize>("jobs", 64);
    let seed = args.num::<u64>("seed", 0);
    let man = Manifest::load(predsamp::artifacts_dir())?;
    let engine = Engine::load(&man, &model)?;
    let bs = *engine.batch_sizes().last().unwrap();
    let exe = engine.exe_for(bs, false)?;
    let d = exe.dim();

    // Batch-1 reference rate (the paper's target for a scheduler).
    let exe1 = engine.exe_for(1, false)?;
    let mut b1_iters = 0usize;
    let b1_jobs = jobs.min(8);
    for id in 0..b1_jobs {
        let mut ps = predsamp::sampler::predictive::PredictiveSampler::new(exe1, Box::new(FpiReuse));
        ps.reset_slot(0, predsamp::sampler::noise::JobNoise::new(seed, id as u64, d, exe1.categories()));
        while !ps.slot_done(0) {
            ps.step()?;
        }
        b1_iters += ps.take_result(0).unwrap().iterations;
    }
    let b1_rate = b1_iters as f64 / b1_jobs as f64;
    println!("batch-1 reference: {b1_rate:.1} ARM calls/job ({:.1}% of d={d})", 100.0 * b1_rate / d as f64);

    let cont = scheduler::run_continuous(exe, Box::new(FpiReuse), jobs, seed)?;
    let sync = scheduler::run_sync_chunks(exe, Box::new(FpiReuse), jobs, seed)?;
    println!("\n{model}, {jobs} jobs, batch {bs}, FPI:");
    for (tag, r) in [("continuous", &cont), ("sync", &sync)] {
        println!(
            "  {tag:<11} passes {:>5}  slot-calls/job {:>6.2} ({:.1}% of d)  occupancy {:>5.1}%  wall {}",
            r.total_passes,
            r.calls_per_job,
            100.0 * r.calls_per_job / d as f64,
            100.0 * r.occupancy,
            fmt_duration(r.wall_secs)
        );
    }
    // Scheduling must never change samples.
    for i in 0..jobs {
        assert_eq!(cont.results[i].x, sync.results[i].x, "job {i}");
    }
    assert!(cont.total_passes <= sync.total_passes, "refill must not lose to sync");
    println!("  ✓ samples identical under both schedulers; continuous ≤ sync passes");
    Ok(())
}
