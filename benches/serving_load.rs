//! Serving-load benchmark: Poisson request arrivals against the TCP
//! server, reporting latency percentiles and throughput for continuous vs
//! synchronous batching. This is the full production path — client
//! sockets, protocol parsing, dynamic batching window, engine, PJRT.
//!
//!     cargo bench --bench serving_load [-- --model mnist_bin --rate 4 --secs 6]

use predsamp::bench::workload::poisson_stream;
use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client};
use predsamp::substrate::rng::Rng;
use predsamp::substrate::stats::{percentile, Summary};
use predsamp::substrate::timer::{fmt_duration, Timer};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = predsamp::substrate::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let model = args.get("model", "mnist_bin");
    let rate = args.num::<f64>("rate", 4.0); // requests/sec
    let secs = args.num::<f64>("secs", 6.0);

    for continuous in [true, false] {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 32,
            max_wait: Duration::from_millis(25),
            continuous,
            worker_threads: 8,
        };
        let server = spawn(predsamp::artifacts_dir(), cfg)?;
        // Warm up (compile executables) outside the measured window.
        let mut warm = Client::connect(&server.addr)?;
        let w = warm.call(&format!(r#"{{"op":"sample","model":"{model}","n":1,"return_samples":false}}"#))?;
        anyhow::ensure!(w.get("ok").as_bool() == Some(true), "warmup failed: {w}");

        let mut rng = Rng::new(7);
        let stream = poisson_stream(&mut rng, rate, secs, (1, 4));
        let n_req = stream.len();
        let lats = Arc::new(Mutex::new(Vec::<f64>::new()));
        let t0 = Timer::start();
        let mut handles = Vec::new();
        let mut total_samples = 0usize;
        for item in stream {
            total_samples += item.n;
            // Open-loop: wait until the arrival time, then fire from a thread.
            let wait = (item.at_secs - t0.secs()).max(0.0);
            std::thread::sleep(Duration::from_secs_f64(wait));
            let addr = server.addr;
            let model = model.clone();
            let lats = Arc::clone(&lats);
            handles.push(std::thread::spawn(move || {
                let t = Timer::start();
                if let Ok(mut c) = Client::connect(&addr) {
                    let _ = c.call(&format!(
                        r#"{{"op":"sample","model":"{model}","method":"fpi","n":{},"seed":{},"return_samples":false}}"#,
                        item.n, item.seed
                    ));
                    lats.lock().unwrap().push(t.secs());
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let wall = t0.secs();
        let lats = lats.lock().unwrap().clone();
        let s = Summary::of(&lats);
        println!(
            "{} batching: {n_req} requests / {total_samples} samples over {}  ({:.1} samples/s)",
            if continuous { "continuous" } else { "sync      " },
            fmt_duration(wall),
            total_samples as f64 / wall
        );
        println!(
            "             latency mean {} p50 {} p95 {} max {}",
            fmt_duration(s.mean),
            fmt_duration(percentile(&lats, 50.0)),
            fmt_duration(percentile(&lats, 95.0)),
            fmt_duration(s.max)
        );
        server.stop();
    }
    Ok(())
}
