//! Serving-load benchmark: a mixed (model, method) request stream against
//! the full TCP serving stack — client sockets, protocol parsing,
//! dispatcher, sharded engine workers, dynamic batching — comparing
//! throughput across engine-worker counts. Runs on the pure-rust mock ARM
//! by default (no artifacts or PJRT needed), so the sharding speedup is
//! measurable anywhere; expected: >= 2x at 4 workers vs 1 on a
//! multi-core host (printed, not asserted — wall-clock ratios are too
//! machine-dependent to gate on).
//!
//!     cargo bench --bench serving_load [-- --clients 8 --requests 12 --engine-threads 1,4]

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::server::{spawn, Client};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::cli::Args;
use predsamp::substrate::stats::{percentile, Summary};
use predsamp::substrate::timer::{fmt_duration, Timer};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The mixed request stream: incompatible (model, method) groups that a
/// single engine thread can only serve head-of-line.
const MIX: [(&str, &str); 4] = [("mock_a", "fpi"), ("mock_b", "fpi"), ("mock_a", "zeros"), ("mock_b", "last")];

fn fixture_dir() -> anyhow::Result<std::path::PathBuf> {
    let dir = std::env::temp_dir().join(format!("predsamp-servebench-{}", std::process::id()));
    write_mock_manifest(&dir, &MockModelSpec::demo_pair())?;
    Ok(dir)
}

fn run_load(dir: std::path::PathBuf, engine_threads: usize, clients: usize, requests: usize) -> anyhow::Result<(f64, Vec<f64>)> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        continuous: true,
        elastic: true,
        steal: true,
        // Every open connection pins one handler thread, so leave headroom
        // beyond the measured clients.
        worker_threads: clients + 2,
        engine_threads,
        ..ServeConfig::default()
    };
    let server = spawn(dir, cfg)?;
    // Warm every (model, method) group so lazy engine setup happens
    // outside the measured window; drop the warm connection before
    // measuring so it doesn't pin a handler thread.
    {
        let mut warm = Client::connect(&server.addr)?;
        for (model, method) in MIX {
            let w = warm.call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":1,"return_samples":false}}"#))?;
            anyhow::ensure!(w.get("ok").as_bool() == Some(true), "warmup failed: {w}");
        }
    }

    let lats = Arc::new(Mutex::new(Vec::<f64>::new()));
    let t0 = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = server.addr;
        let lats = Arc::clone(&lats);
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut client = Client::connect(&addr)?;
            for r in 0..requests {
                let (model, method) = MIX[(c + r) % MIX.len()];
                let t = Timer::start();
                let resp = client.call(&format!(
                    r#"{{"op":"sample","model":"{model}","method":"{method}","n":4,"seed":{},"return_samples":false}}"#,
                    c * 1000 + r
                ))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "request failed: {resp}");
                lats.lock().unwrap().push(t.secs());
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread")?;
    }
    let wall = t0.secs();
    server.stop();
    let lats = lats.lock().unwrap().clone();
    Ok((wall, lats))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let clients = args.num::<usize>("clients", 8);
    let requests = args.num::<usize>("requests", 12);
    let threads_list: Vec<usize> = {
        let l = args.list("engine-threads");
        if l.is_empty() {
            vec![1, 4]
        } else {
            l.iter().filter_map(|s| s.parse().ok()).collect()
        }
    };
    let dir = fixture_dir()?;
    let total_samples = clients * requests * 4;

    println!("serving load: {clients} clients x {requests} requests, n=4, mixed {} groups (mock ARM)", MIX.len());
    let mut throughput = Vec::new();
    for &threads in &threads_list {
        let (wall, lats) = run_load(dir.clone(), threads, clients, requests)?;
        let tput = total_samples as f64 / wall;
        let s = Summary::of(&lats);
        println!(
            "  engine_threads {threads}: {total_samples} samples over {}  ({tput:.1} samples/s)",
            fmt_duration(wall)
        );
        println!(
            "             latency mean {} p50 {} p95 {} max {}",
            fmt_duration(s.mean),
            fmt_duration(percentile(&lats, 50.0)),
            fmt_duration(percentile(&lats, 95.0)),
            fmt_duration(s.max)
        );
        throughput.push(tput);
    }
    if throughput.len() >= 2 {
        let speedup = throughput.last().unwrap() / throughput[0];
        println!(
            "  speedup: {speedup:.2}x at {} workers vs {}",
            threads_list.last().unwrap(),
            threads_list[0]
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
