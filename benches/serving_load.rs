//! Serving-load benchmark: a mixed (model, method) request stream against
//! the full TCP serving stack — client sockets, protocol parsing,
//! dispatcher, sharded engine workers, dynamic batching — comparing
//! throughput across engine-worker counts, plus a placement scenario
//! proving per-model pinning serves the same stream with strictly fewer
//! engine loads than replicate-all. Runs on the pure-rust mock ARM by
//! default (no artifacts or PJRT needed), so both results are measurable
//! anywhere; the sharding speedup is printed, not asserted (wall-clock
//! ratios are too machine-dependent to gate on), while the engine-load
//! comparison *is* asserted (it counts work, not time). Results land in
//! `BENCH_serving_load.json` (uploaded as a CI artifact).
//!
//! A high-concurrency edge scenario additionally drives ≥256 concurrent
//! connections through the connection plane and measures
//! time-to-first-sample for streamed vs group-close delivery (streaming
//! must win — that one *is* asserted, since the streamed event fires jobs
//! before the schedule ends by construction).
//!
//! An edge-*scale* scenario then parks thousands of mostly-idle
//! connections on the plane and serves a small active set through the
//! crowd, once per readiness backend. The per-tick edge cost
//! (ready events / tick, summed over shards) for epoll must come in
//! strictly below scan — O(ready) vs O(conns) is a structural gap, not a
//! wall-clock race — at bitwise-equal outputs. Both gates are asserted.
//!
//! A federation scenario runs the mixed stream one tier up — through a
//! front-tier router over three backend coordinators — asserting the
//! routed outputs bitwise-equal the single-process reference, then stops
//! the backend owning `mock_a` and times the failover (link-error
//! detection + namespace re-home + replay) as one client-visible call.
//!
//!     cargo bench --bench serving_load [-- --clients 8 --requests 12 --engine-threads 1,4 --conns 256 --idle-conns 4096 --fed-requests 16 --out BENCH_serving_load.json]

use predsamp::coordinator::config::ServeConfig;
use predsamp::coordinator::federation::{spawn_router, RouterConfig};
use predsamp::coordinator::placement::PlacementKind;
use predsamp::coordinator::protocol::parse_samples;
use predsamp::coordinator::server::{spawn, Client, ServerHandle};
use predsamp::runtime::artifact::{write_mock_manifest, MockModelSpec};
use predsamp::substrate::cli::Args;
use predsamp::substrate::json::Value;
use predsamp::substrate::readiness::{raise_nofile_limit, ReadinessKind};
use predsamp::substrate::stats::{percentile, Summary};
use predsamp::substrate::timer::{fmt_duration, Timer};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The mixed request stream: incompatible (model, method) groups that a
/// single engine thread can only serve head-of-line.
const MIX: [(&str, &str); 4] = [("mock_a", "fpi"), ("mock_b", "fpi"), ("mock_a", "zeros"), ("mock_b", "last")];

fn fixture_dir() -> anyhow::Result<std::path::PathBuf> {
    let dir = std::env::temp_dir().join(format!("predsamp-servebench-{}", std::process::id()));
    write_mock_manifest(&dir, &MockModelSpec::demo_pair())?;
    Ok(dir)
}

fn run_load(dir: std::path::PathBuf, engine_threads: usize, clients: usize, requests: usize) -> anyhow::Result<(f64, Vec<f64>)> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        continuous: true,
        elastic: true,
        steal: true,
        // All client connections share the default single-shard edge; no
        // per-connection thread sizing is needed.
        engine_threads,
        ..ServeConfig::default()
    };
    let server = spawn(dir, cfg)?;
    // Warm every (model, method) group so lazy engine setup happens
    // outside the measured window; drop the warm connection before
    // measuring so it doesn't pin a handler thread.
    {
        let mut warm = Client::connect(&server.addr)?;
        for (model, method) in MIX {
            let w = warm.call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":1,"return_samples":false}}"#))?;
            anyhow::ensure!(w.get("ok").as_bool() == Some(true), "warmup failed: {w}");
        }
    }

    let lats = Arc::new(Mutex::new(Vec::<f64>::new()));
    let t0 = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = server.addr;
        let lats = Arc::clone(&lats);
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut client = Client::connect(&addr)?;
            for r in 0..requests {
                let (model, method) = MIX[(c + r) % MIX.len()];
                let t = Timer::start();
                let resp = client.call(&format!(
                    r#"{{"op":"sample","model":"{model}","method":"{method}","n":4,"seed":{},"return_samples":false}}"#,
                    c * 1000 + r
                ))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "request failed: {resp}");
                lats.lock().unwrap().push(t.secs());
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread")?;
    }
    let wall = t0.secs();
    server.stop();
    let lats = lats.lock().unwrap().clone();
    Ok((wall, lats))
}

/// One run of the placement scenario: a large `mock_a` group keeps its
/// worker busy while two small requests — a `mock_b` group and a second
/// `mock_a` group — arrive on a 2-worker fleet. Under replicate-all the
/// second `mock_a` group routes to the *idle* worker (least-loaded wins)
/// and pays a redundant lazy engine load there; under pinning it waits
/// for `mock_a`'s only eligible worker instead. Returns the three
/// requests' samples plus the fleet's total `engine_loads` gauge.
fn run_placement(dir: std::path::PathBuf, placement: PlacementKind, big_jobs: usize) -> anyhow::Result<(Vec<Vec<Vec<i32>>>, i64)> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        engine_threads: 2,
        placement,
        ..ServeConfig::default()
    };
    let server = spawn(dir, cfg)?;
    let addr = server.addr;
    let big = std::thread::spawn(move || -> anyhow::Result<Vec<Vec<i32>>> {
        let mut c = Client::connect(&addr)?;
        let r = c.call(&format!(r#"{{"op":"sample","model":"mock_a","method":"fpi","n":{big_jobs},"seed":1}}"#))?;
        anyhow::ensure!(r.get("ok").as_bool() == Some(true), "big request failed: {r}");
        Ok(parse_samples(r.get("samples")).expect("samples"))
    });
    // Wait until the dispatcher has routed the big group (its jobs show
    // up as queue depth) before sending the small requests, so "the big
    // group's worker is busy" is a fact, not a sleep.
    let mut c = Client::connect(&server.addr)?;
    for _ in 0..200 {
        let info = c.call(r#"{"op":"info"}"#)?;
        let depth: i64 = info.get("workers").as_arr().unwrap().iter().map(|w| w.get("queue_depth").as_i64().unwrap_or(0)).sum();
        if depth >= big_jobs as i64 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // The second mock_a group goes out first — one round trip after the
    // routing confirmation, while the big schedule is still running — so
    // replicate-all demonstrably routes it to the other (cold) worker.
    let ra = c.call(r#"{"op":"sample","model":"mock_a","method":"zeros","n":1,"seed":3}"#)?;
    anyhow::ensure!(ra.get("ok").as_bool() == Some(true), "mock_a/zeros request failed: {ra}");
    let rb = c.call(r#"{"op":"sample","model":"mock_b","method":"fpi","n":1,"seed":2}"#)?;
    anyhow::ensure!(rb.get("ok").as_bool() == Some(true), "mock_b request failed: {rb}");
    let big_samples = big.join().expect("big client thread")?;
    // Workers publish their gauges after a turn ends, which can trail the
    // last reply by a beat: read until two consecutive snapshots agree.
    let mut engine_loads = -1i64;
    for _ in 0..40 {
        let m = c.call(r#"{"op":"metrics"}"#)?;
        let now = m.get("metrics").get("engine_loads").as_i64().unwrap_or(-1);
        if now >= 0 && now == engine_loads {
            break;
        }
        engine_loads = now;
        std::thread::sleep(Duration::from_millis(50));
    }
    server.stop();
    let outputs = vec![big_samples, parse_samples(rb.get("samples")).expect("samples"), parse_samples(ra.get("samples")).expect("samples")];
    Ok((outputs, engine_loads))
}

/// High-concurrency edge scenario: `conns` simultaneous connections all
/// multiplexed onto the single event-loop thread (the old edge needed one
/// thread per connection), then time-to-first-sample on the same
/// many-job request delivered streaming vs at group close. Returns
/// `(wall for the pipelined wave, ttfs streaming, ttfs group-close)`.
fn run_edge(dir: std::path::PathBuf, conns: usize) -> anyhow::Result<(f64, f64, f64)> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        max_conns: conns + 8,
        engine_threads: 2,
        ..ServeConfig::default()
    };
    let server = spawn(dir, cfg)?;
    {
        let mut warm = Client::connect(&server.addr)?;
        let w = warm.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":1,"return_samples":false}"#)?;
        anyhow::ensure!(w.get("ok").as_bool() == Some(true), "warmup failed: {w}");
    }

    // Open every connection up front, pipeline one request down each, and
    // only then read the replies back — all `conns` sockets are
    // concurrently open and in flight on the one edge thread.
    let t0 = Timer::start();
    let mut clients = Vec::with_capacity(conns);
    for i in 0..conns {
        let mut c = Client::connect(&server.addr)?;
        c.send_line(&format!(
            r#"{{"op":"sample","model":"mock_a","method":"fpi","n":1,"seed":{i},"return_samples":false,"id":{i}}}"#
        ))?;
        clients.push(c);
    }
    for (i, c) in clients.iter_mut().enumerate() {
        let r = c.read_message()?;
        anyhow::ensure!(r.get("ok").as_bool() == Some(true), "edge request failed: {r}");
        anyhow::ensure!(r.get("id").as_i64() == Some(i as i64), "reply must echo its request id: {r}");
    }
    let wall = t0.secs();
    drop(clients);

    // Time-to-first-sample on one many-job request: streamed delivery
    // hands over the first converged job immediately; group-close
    // delivery pays the whole schedule first.
    let mut c = Client::connect(&server.addr)?;
    let t = Timer::start();
    let mut first = None;
    let fin = c.call_streamed(r#"{"op":"sample","model":"mock_a","method":"fpi","n":64,"seed":7,"stream":true,"return_samples":false}"#, &mut |_| {
        if first.is_none() {
            first = Some(t.secs());
        }
    })?;
    anyhow::ensure!(fin.get("ok").as_bool() == Some(true), "streamed request failed: {fin}");
    let ttfs_stream = first.expect("streamed request produced no events");
    let t = Timer::start();
    let fin = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":64,"seed":7,"return_samples":false}"#)?;
    anyhow::ensure!(fin.get("ok").as_bool() == Some(true), "group-close request failed: {fin}");
    let ttfs_close = t.secs();
    server.stop();
    Ok((wall, ttfs_stream, ttfs_close))
}

/// The fleet's `open_conns` edge gauge, via an existing connection.
fn open_conns(c: &mut Client) -> anyhow::Result<i64> {
    Ok(c.call(r#"{"op":"metrics"}"#)?.get("metrics").get("edge").get("open_conns").as_i64().unwrap_or(0))
}

/// Sum the per-shard `(ticks, ready_events)` counters across the plane.
fn edge_shard_totals(c: &mut Client) -> anyhow::Result<(u64, u64)> {
    let m = c.call(r#"{"op":"metrics"}"#)?;
    let shards = m.get("metrics").get("edge").get("shards").as_arr().expect("edge.shards gauge");
    let (mut ticks, mut events) = (0u64, 0u64);
    for s in shards {
        ticks += s.get("ticks").as_i64().unwrap_or(0) as u64;
        events += s.get("ready_events").as_i64().unwrap_or(0) as u64;
    }
    Ok((ticks, events))
}

/// Edge-scale scenario: park `idle` connections that never send a byte,
/// then serve `active` clients × `rounds` requests through the crowd on
/// the given readiness backend (2 shards). Returns the active clients'
/// sample outputs (the bitwise A/B payload), the per-tick edge cost
/// (ready events per tick over the active window, summed across shards),
/// and the raw `(ticks, events)` deltas behind it. Scan reports every
/// registered connection every tick, so its cost tracks the herd size;
/// epoll reports only what's actually readable.
fn run_edge_scale(
    dir: std::path::PathBuf,
    kind: ReadinessKind,
    idle: usize,
    active: usize,
    rounds: usize,
) -> anyhow::Result<(Vec<Vec<Vec<i32>>>, f64, u64, u64)> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        max_conns: idle + active + 16,
        engine_threads: 2,
        conn_threads: 2,
        readiness: kind,
        ..ServeConfig::default()
    };
    let server = spawn(dir, cfg)?;
    let mut metrics_client = Client::connect(&server.addr)?;
    let w = metrics_client.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":1,"return_samples":false}"#)?;
    anyhow::ensure!(w.get("ok").as_bool() == Some(true), "warmup failed: {w}");

    // Open the idle herd in chunks: the listener's accept backlog is
    // finite, so wait for the edge to adopt each chunk (visible in the
    // `open_conns` gauge) before piling on the next.
    let mut herd = Vec::with_capacity(idle);
    while herd.len() < idle {
        let chunk = (idle - herd.len()).min(100);
        for _ in 0..chunk {
            herd.push(std::net::TcpStream::connect(server.addr)?);
        }
        let want = (herd.len() + 1) as i64; // + the metrics connection
        for _ in 0..500 {
            if open_conns(&mut metrics_client)? >= want {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let adopted = open_conns(&mut metrics_client)?;
    anyhow::ensure!(adopted >= (idle + 1) as i64, "idle herd did not fully connect: {adopted} of {}", idle + 1);

    let (t0, e0) = edge_shard_totals(&mut metrics_client)?;
    let mut handles = Vec::new();
    for a in 0..active {
        let addr = server.addr;
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<Vec<Vec<i32>>>> {
            let mut c = Client::connect(&addr)?;
            let mut out = Vec::with_capacity(rounds);
            for r in 0..rounds {
                let (model, method) = MIX[(a + r) % MIX.len()];
                let resp = c.call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":2,"seed":{}}}"#, a * 100 + r))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "active request failed: {resp}");
                out.push(parse_samples(resp.get("samples")).expect("samples"));
            }
            Ok(out)
        }));
    }
    let mut outputs = Vec::with_capacity(active * rounds);
    for h in handles {
        outputs.extend(h.join().expect("active client thread")?);
    }
    let (t1, e1) = edge_shard_totals(&mut metrics_client)?;
    server.stop();
    drop(herd);
    let (dt, de) = (t1.saturating_sub(t0).max(1), e1.saturating_sub(e0));
    Ok((outputs, de as f64 / dt as f64, dt, de))
}

/// Federation scenario: the mixed stream through a front-tier router
/// over `n` backend coordinators, bitwise-compared against one process
/// serving the same stream directly — then the backend owning `mock_a`
/// stops, and the next `mock_a` request times the whole failover (link
/// error detection, namespace re-home, replay on a survivor) as one
/// client-visible latency. Returns the `federation` result object.
fn run_federation(dir: std::path::PathBuf, n: usize, requests: usize) -> anyhow::Result<Value> {
    fn backend(dir: std::path::PathBuf) -> anyhow::Result<ServerHandle> {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            continuous: true,
            elastic: true,
            steal: true,
            engine_threads: 2,
            ..ServeConfig::default()
        };
        spawn(dir, cfg)
    }
    let stream = |addr: &std::net::SocketAddr| -> anyhow::Result<(Vec<Vec<Vec<i32>>>, f64)> {
        let mut c = Client::connect(addr)?;
        let t = Timer::start();
        let mut out = Vec::with_capacity(requests);
        for i in 0..requests {
            let (model, method) = MIX[i % MIX.len()];
            let r = c.call(&format!(r#"{{"op":"sample","model":"{model}","method":"{method}","n":2,"seed":{i}}}"#))?;
            anyhow::ensure!(r.get("ok").as_bool() == Some(true), "request failed: {r}");
            out.push(parse_samples(r.get("samples")).expect("samples"));
        }
        Ok((out, t.secs()))
    };

    let direct = backend(dir.clone())?;
    let (reference, direct_wall) = stream(&direct.addr)?;
    direct.stop();

    let mut backends: Vec<Option<ServerHandle>> =
        (0..n).map(|_| backend(dir.clone()).map(Some)).collect::<anyhow::Result<_>>()?;
    let router = spawn_router(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: backends.iter().map(|b| b.as_ref().unwrap().addr.to_string()).collect(),
        probe_interval: Duration::from_millis(100),
        ..RouterConfig::default()
    })?;
    let (routed, routed_wall) = stream(&router.addr)?;
    anyhow::ensure!(routed == reference, "federated outputs diverged from the single process");

    // Find `mock_a`'s owner: the per-backend forward counter that moves
    // when one more mock_a request goes through.
    let mut c = Client::connect(&router.addr)?;
    let counts = |c: &mut Client| -> anyhow::Result<Vec<i64>> {
        Ok(c.call(r#"{"op":"metrics"}"#)?
            .get("metrics")
            .get("fleet")
            .get("backends")
            .as_arr()
            .expect("fleet.backends gauge")
            .iter()
            .map(|b| b.get("forwarded").as_i64().unwrap_or(0))
            .collect())
    };
    let before = counts(&mut c)?;
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":1,"seed":9000,"return_samples":false}"#)?;
    anyhow::ensure!(r.get("ok").as_bool() == Some(true), "owner probe failed: {r}");
    let after = counts(&mut c)?;
    let owner = after.iter().zip(&before).position(|(a, b)| a > b).expect("the owner's forward counter moved");

    // Stop the owner and time the next mock_a request end to end: the
    // router detects the dead link, re-homes the namespace, and replays
    // on a survivor — all inside this one client-visible call.
    backends[owner].take().expect("owner still running").stop();
    let t = Timer::start();
    let r = c.call(r#"{"op":"sample","model":"mock_a","method":"fpi","n":2,"seed":0}"#)?;
    let rehome_latency = t.secs();
    anyhow::ensure!(r.get("ok").as_bool() == Some(true), "post-failover request failed: {r}");
    anyhow::ensure!(
        parse_samples(r.get("samples")).expect("samples") == reference[0],
        "failover changed the payload"
    );
    let fleet = c.call(r#"{"op":"metrics"}"#)?.get("metrics").get("fleet").clone();
    router.stop();
    for b in backends.into_iter().flatten() {
        b.stop();
    }

    println!(
        "federation: {n} backends behind 1 router, {requests} mixed requests routed in {} (direct {}), outputs bitwise equal",
        fmt_duration(routed_wall),
        fmt_duration(direct_wall)
    );
    println!(
        "            failover: owner stopped, next request re-homed + replayed in {} ({} re-homes, {} forwards)",
        fmt_duration(rehome_latency),
        fleet.get("re_homes").as_i64().unwrap_or(0),
        fleet.get("forwards").as_i64().unwrap_or(0)
    );
    Ok(Value::obj(vec![
        ("backends", Value::num(n as f64)),
        ("requests", Value::num(requests as f64)),
        ("direct_wall_secs", Value::num(direct_wall)),
        ("routed_wall_secs", Value::num(routed_wall)),
        ("routed_overhead", Value::num(routed_wall / direct_wall.max(1e-9))),
        ("rehome_latency_s", Value::num(rehome_latency)),
        ("re_homes", Value::num(fleet.get("re_homes").as_i64().unwrap_or(0) as f64)),
        ("forwards", Value::num(fleet.get("forwards").as_i64().unwrap_or(0) as f64)),
        ("outputs_bitwise_equal", Value::Bool(true)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let clients = args.num::<usize>("clients", 8);
    let requests = args.num::<usize>("requests", 12);
    let out_path = args.get("out", "BENCH_serving_load.json");
    let threads_list: Vec<usize> = {
        let l = args.list("engine-threads");
        if l.is_empty() {
            vec![1, 4]
        } else {
            l.iter().filter_map(|s| s.parse().ok()).collect()
        }
    };
    let dir = fixture_dir()?;
    let total_samples = clients * requests * 4;

    println!("serving load: {clients} clients x {requests} requests, n=4, mixed {} groups (mock ARM)", MIX.len());
    let mut throughput = Vec::new();
    let mut shard_values = Vec::new();
    for &threads in &threads_list {
        let (wall, lats) = run_load(dir.clone(), threads, clients, requests)?;
        let tput = total_samples as f64 / wall;
        let s = Summary::of(&lats);
        println!(
            "  engine_threads {threads}: {total_samples} samples over {}  ({tput:.1} samples/s)",
            fmt_duration(wall)
        );
        println!(
            "             latency mean {} p50 {} p95 {} max {}",
            fmt_duration(s.mean),
            fmt_duration(percentile(&lats, 50.0)),
            fmt_duration(percentile(&lats, 95.0)),
            fmt_duration(s.max)
        );
        shard_values.push(Value::obj(vec![
            ("engine_threads", Value::num(threads as f64)),
            ("samples", Value::num(total_samples as f64)),
            ("wall_secs", Value::num(wall)),
            ("samples_per_s", Value::num(tput)),
            ("latency_p50_s", Value::num(percentile(&lats, 50.0))),
            ("latency_p95_s", Value::num(percentile(&lats, 95.0))),
        ]));
        throughput.push(tput);
    }
    let mut speedup = None;
    if throughput.len() >= 2 {
        let s = throughput.last().unwrap() / throughput[0];
        println!(
            "  speedup: {s:.2}x at {} workers vs {}",
            threads_list.last().unwrap(),
            threads_list[0]
        );
        speedup = Some(s);
    }

    // Placement scenario: the same three-request stream under
    // replicate-all vs per-model pinning. Outputs must be bitwise equal;
    // pinning must pay strictly fewer lazy engine loads (replicate-all
    // loads mock_a on the idle second worker; pinning never does).
    let big_jobs = args.num::<usize>("big-jobs", 256);
    let pinned_kind = PlacementKind::Pinned(vec![("mock_a".to_string(), vec![0]), ("mock_b".to_string(), vec![1])]);
    let (rep_out, rep_loads) = run_placement(dir.clone(), PlacementKind::ReplicateAll, big_jobs)?;
    let (pin_out, pin_loads) = run_placement(dir.clone(), pinned_kind, big_jobs)?;
    println!("placement: replicate-all {rep_loads} engine loads vs pinned {pin_loads} (same {big_jobs}+1+1-job stream)");
    assert_eq!(rep_out, pin_out, "placement must not change any sample");
    assert!(
        pin_loads < rep_loads,
        "pinning must pay strictly fewer engine loads than replicate-all: pinned {pin_loads} vs replicated {rep_loads}"
    );

    // Edge scenario: ≥256 concurrent connections on the single event-loop
    // thread, plus streaming vs group-close time-to-first-sample. The
    // thread count is structural (one loop regardless of connections), and
    // streamed delivery must beat waiting for the group to close.
    let conns = args.num::<usize>("conns", 256);
    let (edge_wall, ttfs_stream, ttfs_close) = run_edge(dir.clone(), conns)?;
    println!(
        "edge: {conns} concurrent connections on 1 event-loop thread ({:.2} threads/1k conns), wave {}",
        1000.0 / conns as f64,
        fmt_duration(edge_wall)
    );
    println!("      time-to-first-sample (n=64): streaming {} vs group-close {}", fmt_duration(ttfs_stream), fmt_duration(ttfs_close));
    assert!(
        ttfs_stream < ttfs_close,
        "streamed first sample must land strictly before group-close delivery: {ttfs_stream:.4}s vs {ttfs_close:.4}s"
    );

    // Edge-scale scenario: thousands of mostly-idle connections, served
    // through on every supported readiness backend. The process holds both
    // ends of every socket, so the herd is clamped to half the open-file
    // limit (raised to the hard cap first) minus slack.
    let limit = raise_nofile_limit();
    let idle_req = args.num::<usize>("idle-conns", 4096);
    let idle = idle_req.min(((limit / 2).saturating_sub(256)) as usize).max(64);
    if idle < idle_req {
        println!("edge-scale: clamped idle connections {idle_req} -> {idle} (open-file limit {limit})");
    }
    let (active, rounds) = (16usize, 4usize);
    let mut scale_results = Vec::new();
    for kind in [ReadinessKind::Scan, ReadinessKind::Epoll] {
        if !kind.supported() {
            continue;
        }
        let (outputs, cost, ticks, events) = run_edge_scale(dir.clone(), kind, idle, active, rounds)?;
        println!(
            "edge-scale [{}]: {idle} idle + {active} active conns, {cost:.1} ready events/tick ({events} events over {ticks} ticks)",
            kind.label()
        );
        scale_results.push((kind, outputs, cost, ticks, events));
    }
    let mut edge_scale_fields = vec![
        ("idle_conns", Value::num(idle as f64)),
        ("active_conns", Value::num(active as f64)),
        ("rounds", Value::num(rounds as f64)),
        ("outputs_bitwise_equal", Value::Bool(scale_results.len() == 2)),
    ];
    for (kind, _, cost, ticks, events) in &scale_results {
        edge_scale_fields.push((
            kind.label(),
            Value::obj(vec![
                ("ticks", Value::num(*ticks as f64)),
                ("ready_events", Value::num(*events as f64)),
                ("ready_per_tick", Value::num(*cost)),
            ]),
        ));
    }
    if let [(_, scan_out, scan_cost, ..), (_, epoll_out, epoll_cost, ..)] = &scale_results[..] {
        assert_eq!(scan_out, epoll_out, "readiness backend must not change any sample");
        assert!(
            epoll_cost < scan_cost,
            "epoll per-tick edge cost must be strictly below scan with {idle} idle connections: {epoll_cost:.1} vs {scan_cost:.1}"
        );
        println!("edge-scale: epoll {epoll_cost:.1} ready/tick vs scan {scan_cost:.1} — O(ready) beats O(conns), outputs bitwise equal");
    }

    // Federation scenario: the same stream one tier up, through a router
    // over three backend coordinators, including a timed failover.
    let fed_requests = args.num::<usize>("fed-requests", 16);
    let federation = run_federation(dir.clone(), 3, fed_requests)?;

    let mut root = vec![
        ("bench", Value::str("serving_load")),
        ("clients", Value::num(clients as f64)),
        ("requests", Value::num(requests as f64)),
        ("sharding", Value::Arr(shard_values)),
        (
            "edge",
            Value::obj(vec![
                ("conns", Value::num(conns as f64)),
                ("conn_plane_threads", Value::num(1.0)),
                ("threads_per_1k_conns", Value::num(1000.0 / conns as f64)),
                ("wave_wall_secs", Value::num(edge_wall)),
                ("ttfs_stream_s", Value::num(ttfs_stream)),
                ("ttfs_close_s", Value::num(ttfs_close)),
            ]),
        ),
        ("edge_scale", Value::obj(edge_scale_fields)),
        (
            "placement",
            Value::obj(vec![
                ("big_jobs", Value::num(big_jobs as f64)),
                ("replicated_engine_loads", Value::num(rep_loads as f64)),
                ("pinned_engine_loads", Value::num(pin_loads as f64)),
                ("outputs_bitwise_equal", Value::Bool(true)),
            ]),
        ),
        ("federation", federation),
    ];
    if let Some(s) = speedup {
        root.push(("sharding_speedup", Value::num(s)));
    }
    std::fs::write(&out_path, Value::obj(root).to_string())?;
    println!("wrote {out_path}");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
