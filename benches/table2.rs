//! Regenerates the paper's Table 2 (latent-space ARMs over the discrete
//! autoencoder): baseline / FPI / FPI+forecasting(T=1) on the svhn, cifar
//! and imagenet32 latent priors.
//!
//!     cargo bench --bench table2 [-- --seeds 10 --batches 1,32 --models latent_cifar]

use predsamp::bench::tables;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let seeds: Vec<u64> = (0..args.num::<usize>("seeds", 2) as u64).collect();
    let batches: Vec<usize> = {
        let l = args.list("batches");
        if l.is_empty() { vec![1, 32] } else { l.iter().filter_map(|s| s.parse().ok()).collect() }
    };
    let models = args.list("models");
    let man = Manifest::load(predsamp::artifacts_dir())?;
    let rows = tables::table2(&man, &seeds, &batches, &models)?;

    for r in &rows {
        if r.method == "fpi" {
            assert!(
                r.calls_pct.mean < 60.0,
                "latent FPI should need well under the baseline's calls ({}: {:.1}%)",
                r.model,
                r.calls_pct.mean
            );
        }
    }
    println!("\ntable2 done ({} rows)", rows.len());
    Ok(())
}
