//! Regenerates the paper's Figure 6: per-location convergence iteration of
//! fixed-point iteration vs the baseline on a latent-space ARM, averaged
//! over a batch of 32 samples and all channels (log-scale heatmap PPM).
//!
//!     cargo bench --bench fig6_convergence [-- --model latent_cifar --seed 10]

use predsamp::bench::figures;
use predsamp::runtime::artifact::Manifest;
use predsamp::substrate::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let model = args.get("model", "latent_cifar");
    let seed = args.num::<u64>("seed", 10);
    let man = Manifest::load(predsamp::artifacts_dir())?;
    let written = figures::fig6(&man, &model, std::path::Path::new("results"), seed)?;
    for w in written {
        println!("wrote {w}");
    }
    Ok(())
}
